//! `alto` — the leader binary: task intake, scheduling, batched
//! multi-LoRA execution with early exit, over real PJRT artifacts or the
//! simulated H100 cluster.
//!
//! Subcommands:
//!
//! ```text
//! info                         runtime + artifact inventory
//! run    --tasks <spec.json>   multi-task service (simulated cluster)
//! train  --artifact <key>      real PJRT sweep on a tiny-family model
//! sched  --tasks <spec.json>   plan placement only (prints the Gantt)
//! calibrate --artifact <key>   measure real step time / host GFLOPs
//! ```

use alto::api::{EarlyExit, Engine};
use alto::config::TaskSpec;
use alto::coordinator::task_runner::RunConfig;
use alto::data::corpus::Corpus;
use alto::runtime::{Manifest, Runtime};
use alto::train::{calibrate_step_time, run_real_sweep};
use alto::util::cli::Args;

use anyhow::{Context, Result};

const USAGE: &str = "usage: alto <info|run|train|sched|calibrate> [options]
  info                              list artifacts + runtime platform
  run    --tasks spec.json [--gpus 8] [--no-early-exit]
  train  --artifact sft_nano_n4_b2_t32_r8 [--steps 100] [--configs 8]
  sched  --tasks spec.json [--gpus 8] [--policy optimal|sjf|fcfs|lpt]
  calibrate --artifact sft_nano_n4_b2_t32_r8 [--steps 20]";

fn main() -> Result<()> {
    let args = Args::from_env(&["no-early-exit", "help"]);
    if args.has_flag("help") {
        println!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("run") => cmd_run(&args),
        Some("train") => cmd_train(&args),
        Some("sched") => cmd_sched(&args),
        Some("calibrate") => cmd_calibrate(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    match Manifest::load(artifacts_dir(args)) {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for (key, a) in &m.artifacts {
                println!(
                    "  {key}: {} {} params={} N={} B={} T={} r_max={}",
                    a.kind,
                    a.model.name,
                    a.model.param_count,
                    a.n,
                    a.b,
                    a.t,
                    a.r_max
                );
            }
        }
        Err(e) => println!("no artifacts: {e:#}"),
    }
    Ok(())
}

fn load_tasks(args: &Args) -> Result<Vec<TaskSpec>> {
    let path = args.get("tasks").context("--tasks <spec.json> required")?;
    TaskSpec::load_file(path)
}

fn cmd_run(args: &Args) -> Result<()> {
    let tasks = load_tasks(args)?;
    let gpus = args.get_usize("gpus", 8);
    let engine = Engine::new("adapter_parallel", gpus);
    let ee = if args.has_flag("no-early-exit") {
        EarlyExit::disabled()
    } else {
        EarlyExit::new()
    };
    let outcomes = engine.batched_execution(&tasks, ee)?;
    println!(
        "{:<16} {:>5} {:>12} {:>10} {:>8}",
        "task", "gpus", "duration(s)", "best-val", "saved%"
    );
    for o in &outcomes {
        println!(
            "{:<16} {:>5} {:>12.1} {:>10.4} {:>8.1}",
            o.name,
            o.gpus,
            o.actual_duration,
            o.best_val,
            100.0 * (1.0 - o.samples_used as f64 / o.samples_budget.max(1) as f64)
        );
    }
    Ok(())
}

fn cmd_sched(args: &Args) -> Result<()> {
    use alto::sched::solver::{fcfs_schedule, lpt_schedule, sjf_schedule, solve, SchedTask};
    let tasks = load_tasks(args)?;
    let gpus = args.get_usize("gpus", 8);
    let engine = Engine::new("adapter_parallel", gpus);
    let mut profiler = alto::coordinator::Profiler::new(engine.gpu.clone());
    let st: Vec<SchedTask> = tasks
        .iter()
        .enumerate()
        .map(|(i, t)| SchedTask {
            id: i,
            duration: profiler.estimate_duration(
                &alto::config::MODEL_FAMILY.get(&t.model).expect("model"),
                t,
                engine.n_slots,
            ),
            gpus: t.num_gpus,
        })
        .collect();
    let plan = match args.get_or("policy", "optimal") {
        "sjf" => sjf_schedule(&st, gpus),
        "fcfs" => fcfs_schedule(&st, gpus),
        "lpt" => lpt_schedule(&st, gpus),
        _ => solve(&st, gpus)?,
    };
    println!("makespan: {:.1}s", plan.makespan);
    for p in &plan.placements {
        let t = &tasks[p.id];
        println!(
            "  [{:>8.1}s + {:>8.1}s] {:<16} ({} GPUs)",
            p.start, st[p.id].duration, t.name, p.gpus
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let key = args.get_or("artifact", "sft_nano_n4_b2_t32_r8").to_string();
    let spec = manifest.get(&key)?.clone();
    let steps = args.get_usize("steps", 100);
    let n_cfg = args.get_usize("configs", 8);
    let corpus = Corpus::build("gsm-syn", 512, 64, spec.t, 7)?;
    let lrs = [1e-4, 5e-4, 2e-3, 5e-3];
    let ranks = [2usize, 4, 8];
    let configs: Vec<_> = (0..n_cfg)
        .map(|i| alto::config::HyperParams {
            lr: lrs[i % lrs.len()],
            rank: ranks[(i / lrs.len()) % ranks.len()].min(spec.r_max),
            batch_size: spec.b,
        })
        .collect();
    println!(
        "real sweep: {} configs × {steps} steps on {key}",
        configs.len()
    );
    let out = run_real_sweep(
        &rt,
        &manifest,
        &key,
        corpus,
        &configs,
        steps,
        &RunConfig::default(),
        42,
    )?;
    let res = &out.result;
    println!(
        "best: job {} ({}) val {:.4}; samples used {}/{} ({:.0}% saved)",
        res.best_job,
        res.jobs[res.best_job].hp.label(),
        res.best_val(),
        res.samples_used,
        res.samples_budget,
        100.0 * res.savings_ratio()
    );
    for j in &res.jobs {
        println!(
            "  job {:>2} {:<18} steps {:>5} best-val {:>8.4} exit {:?}",
            j.id,
            j.hp.label(),
            j.steps_run,
            j.best_val,
            j.exit_reason().map(|r| r.as_str()).unwrap_or("-")
        );
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let key = args.get_or("artifact", "sft_nano_n4_b2_t32_r8").to_string();
    let spec = manifest.get(&key)?.clone();
    let corpus = Corpus::build("gsm-syn", 256, 16, spec.t, 7)?;
    let steps = args.get_usize("steps", 20);
    let cal = calibrate_step_time(&rt, &manifest, &key, corpus, steps)?;
    println!(
        "{key}: {:.2} ms/step, {:.2e} flops/step, {:.2} effective GFLOP/s",
        cal.step_seconds * 1e3,
        cal.model_flops_per_step,
        cal.effective_gflops
    );
    Ok(())
}
