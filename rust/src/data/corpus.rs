//! Tokenized datasets with train/val/test splits and the [N, B, T] batch
//! builder the executor feeds to the AOT train step.

use crate::util::rng::Pcg32;

use super::synth::{self, Example, PrefExample};
use super::tokenizer;

/// One tokenized SFT example.
#[derive(Debug, Clone)]
pub struct Encoded {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    /// Raw strings retained for decode-time accuracy evaluation.
    pub prompt: String,
    pub answer: String,
}

/// A split dataset of fixed-length sequences.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub name: String,
    pub seq_len: usize,
    pub train: Vec<Encoded>,
    pub val: Vec<Encoded>,
    pub test: Vec<Encoded>,
}

impl Corpus {
    /// Build a seeded corpus.  Splits follow the paper's GSM8K recipe:
    /// 90% train / 10% val of the "training set", plus a held-out test set.
    pub fn build(
        dataset: &str,
        n_train_pool: usize,
        n_test: usize,
        seq_len: usize,
        seed: u64,
    ) -> anyhow::Result<Corpus> {
        let gen = sft_generator(dataset)?;
        let mut rng = Pcg32::seeded(seed ^ dataset_hash(dataset));
        let mut pool: Vec<Encoded> = (0..n_train_pool)
            .map(|_| encode_one(&gen(&mut rng), seq_len))
            .collect();
        let n_val = (n_train_pool / 10).max(1);
        let val = pool.split_off(n_train_pool - n_val);
        let test = (0..n_test)
            .map(|_| encode_one(&gen(&mut rng), seq_len))
            .collect();
        Ok(Corpus {
            name: dataset.to_string(),
            seq_len,
            train: pool,
            val,
            test,
        })
    }

    /// Batch of shape [n_adapters, batch, seq]: adapter `i` draws its own
    /// reproducible sample stream (fork per adapter), so co-located jobs
    /// see independent data — matching per-job dataloaders in the paper.
    pub fn train_batch(
        &self,
        n_adapters: usize,
        batch: usize,
        step: u64,
        seed: u64,
    ) -> Batch {
        let mut tokens = Vec::with_capacity(n_adapters * batch * self.seq_len);
        let mut targets = Vec::with_capacity(n_adapters * batch * self.seq_len);
        for a in 0..n_adapters {
            let mut rng =
                Pcg32::new(seed ^ (a as u64) << 32 ^ step, 0x5eed ^ a as u64);
            for _ in 0..batch {
                let ex = &self.train[rng.below(self.train.len() as u64) as usize];
                tokens.extend_from_slice(&ex.tokens);
                targets.extend_from_slice(&ex.targets);
            }
        }
        Batch {
            n: n_adapters,
            b: batch,
            t: self.seq_len,
            tokens,
            targets,
        }
    }

    /// Deterministic validation batch (same for every adapter and step, so
    /// val losses are comparable across jobs — required by warmup ranking).
    pub fn val_batch(&self, n_adapters: usize, batch: usize) -> Batch {
        let mut tokens = Vec::new();
        let mut targets = Vec::new();
        for _ in 0..n_adapters {
            for i in 0..batch {
                let ex = &self.val[i % self.val.len()];
                tokens.extend_from_slice(&ex.tokens);
                targets.extend_from_slice(&ex.targets);
            }
        }
        Batch {
            n: n_adapters,
            b: batch,
            t: self.seq_len,
            tokens,
            targets,
        }
    }
}

/// Flat [N, B, T] token + target buffers, row-major.
#[derive(Debug, Clone)]
pub struct Batch {
    pub n: usize,
    pub b: usize,
    pub t: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

impl Batch {
    pub fn dims(&self) -> [usize; 3] {
        [self.n, self.b, self.t]
    }
}

/// Preference corpus for DPO.
#[derive(Debug, Clone)]
pub struct PrefCorpus {
    pub seq_len: usize,
    pub train: Vec<PrefEncoded>,
    pub val: Vec<PrefEncoded>,
}

#[derive(Debug, Clone)]
pub struct PrefEncoded {
    pub tok_c: Vec<i32>,
    pub tgt_c: Vec<i32>,
    pub tok_r: Vec<i32>,
    pub tgt_r: Vec<i32>,
}

impl PrefCorpus {
    pub fn build(n_train: usize, seq_len: usize, seed: u64) -> PrefCorpus {
        let mut rng = Pcg32::seeded(seed ^ 0x9ef);
        let mut pool: Vec<PrefEncoded> = (0..n_train + n_train / 10)
            .map(|_| encode_pref(&synth::pref_syn(&mut rng), seq_len))
            .collect();
        let val = pool.split_off(n_train);
        PrefCorpus {
            seq_len,
            train: pool,
            val,
        }
    }

    pub fn train_batch(&self, n_adapters: usize, batch: usize, step: u64, seed: u64) -> PrefBatch {
        let mut out = PrefBatch::empty(n_adapters, batch, self.seq_len);
        for a in 0..n_adapters {
            let mut rng = Pcg32::new(seed ^ ((a as u64) << 32) ^ step, 0xd9 ^ a as u64);
            for _ in 0..batch {
                let ex = &self.train[rng.below(self.train.len() as u64) as usize];
                out.push(ex);
            }
        }
        out
    }

    pub fn val_batch(&self, n_adapters: usize, batch: usize) -> PrefBatch {
        let mut out = PrefBatch::empty(n_adapters, batch, self.seq_len);
        for _ in 0..n_adapters {
            for i in 0..batch {
                out.push(&self.val[i % self.val.len()]);
            }
        }
        out
    }
}

#[derive(Debug, Clone)]
pub struct PrefBatch {
    pub n: usize,
    pub b: usize,
    pub t: usize,
    pub tok_c: Vec<i32>,
    pub tgt_c: Vec<i32>,
    pub tok_r: Vec<i32>,
    pub tgt_r: Vec<i32>,
}

impl PrefBatch {
    fn empty(n: usize, b: usize, t: usize) -> PrefBatch {
        let cap = n * b * t;
        PrefBatch {
            n,
            b,
            t,
            tok_c: Vec::with_capacity(cap),
            tgt_c: Vec::with_capacity(cap),
            tok_r: Vec::with_capacity(cap),
            tgt_r: Vec::with_capacity(cap),
        }
    }

    fn push(&mut self, ex: &PrefEncoded) {
        self.tok_c.extend_from_slice(&ex.tok_c);
        self.tgt_c.extend_from_slice(&ex.tgt_c);
        self.tok_r.extend_from_slice(&ex.tok_r);
        self.tgt_r.extend_from_slice(&ex.tgt_r);
    }
}

fn encode_one(ex: &Example, seq_len: usize) -> Encoded {
    let (tokens, targets) = tokenizer::encode_example(&ex.prompt, &ex.answer, seq_len);
    Encoded {
        tokens,
        targets,
        prompt: ex.prompt.clone(),
        answer: ex.answer.clone(),
    }
}

fn encode_pref(p: &PrefExample, seq_len: usize) -> PrefEncoded {
    let (tok_c, tgt_c) = tokenizer::encode_example(&p.prompt, &p.chosen, seq_len);
    let (tok_r, tgt_r) = tokenizer::encode_example(&p.prompt, &p.rejected, seq_len);
    PrefEncoded {
        tok_c,
        tgt_c,
        tok_r,
        tgt_r,
    }
}

type SftGen = Box<dyn Fn(&mut Pcg32) -> Example>;

fn sft_generator(dataset: &str) -> anyhow::Result<SftGen> {
    match dataset {
        "gsm-syn" => Ok(Box::new(synth::gsm_syn)),
        "instr-syn" => Ok(Box::new(synth::instr_syn)),
        "reason-syn" => Ok(Box::new(synth::reason_syn)),
        other => anyhow::bail!("unknown SFT dataset '{other}'"),
    }
}

/// Stable per-dataset seed tweak (FNV-1a) so two datasets built with the
/// same user seed still produce disjoint sample streams.
fn dataset_hash(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_sizes() {
        let c = Corpus::build("gsm-syn", 100, 20, 32, 0).unwrap();
        assert_eq!(c.train.len(), 90);
        assert_eq!(c.val.len(), 10);
        assert_eq!(c.test.len(), 20);
    }

    #[test]
    fn deterministic_across_builds() {
        let a = Corpus::build("instr-syn", 50, 5, 32, 7).unwrap();
        let b = Corpus::build("instr-syn", 50, 5, 32, 7).unwrap();
        assert_eq!(a.train[0].tokens, b.train[0].tokens);
        assert_eq!(a.test[4].prompt, b.test[4].prompt);
    }

    #[test]
    fn different_datasets_differ() {
        let a = Corpus::build("gsm-syn", 20, 2, 32, 7).unwrap();
        let b = Corpus::build("instr-syn", 20, 2, 32, 7).unwrap();
        assert_ne!(a.train[0].prompt, b.train[0].prompt);
    }

    #[test]
    fn batch_shape_and_padding() {
        let c = Corpus::build("gsm-syn", 64, 8, 40, 1).unwrap();
        let b = c.train_batch(3, 4, 0, 99);
        assert_eq!(b.dims(), [3, 4, 40]);
        assert_eq!(b.tokens.len(), 3 * 4 * 40);
        assert_eq!(b.targets.len(), 3 * 4 * 40);
        // all tokens in vocab range
        assert!(b.tokens.iter().all(|&t| (0..tokenizer::VOCAB_SIZE as i32).contains(&t)));
    }

    #[test]
    fn adapters_see_different_data() {
        let c = Corpus::build("gsm-syn", 64, 8, 40, 1).unwrap();
        let b = c.train_batch(2, 4, 0, 99);
        let per = 4 * 40;
        assert_ne!(&b.tokens[..per], &b.tokens[per..2 * per]);
    }

    #[test]
    fn val_batch_same_for_all_adapters() {
        let c = Corpus::build("gsm-syn", 64, 8, 40, 1).unwrap();
        let b = c.val_batch(2, 4);
        let per = 4 * 40;
        assert_eq!(&b.tokens[..per], &b.tokens[per..2 * per]);
    }

    #[test]
    fn train_batches_vary_with_step() {
        let c = Corpus::build("gsm-syn", 64, 8, 40, 1).unwrap();
        let b0 = c.train_batch(1, 4, 0, 5);
        let b1 = c.train_batch(1, 4, 1, 5);
        assert_ne!(b0.tokens, b1.tokens);
    }

    #[test]
    fn pref_corpus_batches() {
        let p = PrefCorpus::build(40, 32, 3);
        assert_eq!(p.train.len(), 40);
        assert_eq!(p.val.len(), 4);
        let b = p.train_batch(2, 3, 0, 1);
        assert_eq!(b.tok_c.len(), 2 * 3 * 32);
        assert_eq!(b.tok_r.len(), 2 * 3 * 32);
        assert_ne!(b.tgt_c, b.tgt_r);
    }

    #[test]
    fn unknown_dataset_errors() {
        assert!(Corpus::build("bogus", 10, 2, 16, 0).is_err());
    }
}
