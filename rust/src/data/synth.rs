//! Synthetic datasets replacing GSM8K / Tulu-3 / OpenThoughts3 /
//! UltraFeedback (DESIGN.md §3).  All are seeded grammars, so every split
//! is reproducible and `gsm-syn` has *parseable exact answers*, which
//! gives the quality experiments (Fig 10/14) a real accuracy metric.

use crate::util::rng::Pcg32;

/// One supervised example.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    pub prompt: String,
    pub answer: String,
}

/// One preference example (DPO).
#[derive(Debug, Clone, PartialEq)]
pub struct PrefExample {
    pub prompt: String,
    pub chosen: String,
    pub rejected: String,
}

const NAMES: &[&str] = &[
    "Tom", "Mia", "Sam", "Ava", "Leo", "Zoe", "Max", "Ivy", "Ben", "Amy",
];
const ITEMS: &[&str] = &[
    "apples", "pens", "books", "coins", "cards", "cups", "keys", "hats",
];
const COLORS: &[&str] = &["red", "blue", "green", "gold", "pink", "gray"];
const ANIMALS: &[&str] = &["cat", "dog", "fox", "owl", "bee", "ant"];

/// gsm-syn: 1–3-step arithmetic word problems with integer answers.
/// The GSM8K stand-in: answers parse exactly, so "strict answer parsing"
/// accuracy (paper §8.1) is computable.
pub fn gsm_syn(rng: &mut Pcg32) -> Example {
    let name = *rng.choice(NAMES);
    let item = *rng.choice(ITEMS);
    let steps = rng.range_usize(1, 3);
    let mut total = rng.range_i64(2, 9);
    let mut prompt = format!("{name} has {total} {item}.");
    for _ in 0..steps {
        // losing is only available while there is something to lose
        let op = if total >= 2 { rng.range_usize(0, 2) } else { 0 };
        match op {
            0 => {
                let k = rng.range_i64(1, 9);
                prompt.push_str(&format!(" {name} gets {k} more."));
                total += k;
            }
            1 => {
                let k = rng.range_i64(1, total - 1);
                prompt.push_str(&format!(" {name} loses {k}."));
                total -= k;
            }
            _ => {
                let k = rng.range_i64(2, 3);
                prompt.push_str(&format!(" The {item} double {k_text}.", k_text = if k == 2 { "once" } else { "twice" }));
                for _ in 0..(k - 1) {
                    total *= 2;
                }
            }
        }
    }
    prompt.push_str(&format!(" How many {item} now?"));
    Example {
        prompt,
        answer: total.to_string(),
    }
}

/// instr-syn: short instruction-following pairs (the Tulu-3 stand-in;
/// evaluated by completion loss only, like the paper).
pub fn instr_syn(rng: &mut Pcg32) -> Example {
    match rng.range_usize(0, 3) {
        0 => {
            let n = rng.range_usize(2, 4);
            let mut items: Vec<&str> = COLORS.to_vec();
            rng.shuffle(&mut items);
            Example {
                prompt: format!("List {n} colors."),
                answer: items[..n].join(", "),
            }
        }
        1 => {
            let a = *rng.choice(ANIMALS);
            Example {
                prompt: format!("Repeat the word {a} twice."),
                answer: format!("{a} {a}"),
            }
        }
        2 => {
            let w = *rng.choice(ITEMS);
            Example {
                prompt: format!("Spell {w} backwards."),
                answer: w.chars().rev().collect(),
            }
        }
        _ => {
            let x = rng.range_i64(1, 20);
            Example {
                prompt: format!("Count from {x} to {}.", x + 3),
                answer: (x..=x + 3)
                    .map(|v| v.to_string())
                    .collect::<Vec<_>>()
                    .join(" "),
            }
        }
    }
}

/// reason-syn: longer multi-step chains (the OpenThoughts3 stand-in;
/// roughly 2× the sequence length of the other sets, like OT3's 2048 vs
/// 1024 in the paper).
pub fn reason_syn(rng: &mut Pcg32) -> Example {
    let mut v = rng.range_i64(1, 9);
    let steps = rng.range_usize(3, 6);
    let mut chain = format!("Start with {v}.");
    let mut work = String::new();
    for _ in 0..steps {
        let op = rng.range_usize(0, 2);
        let k = rng.range_i64(1, 5);
        match op {
            0 => {
                chain.push_str(&format!(" Add {k}."));
                v += k;
            }
            1 => {
                chain.push_str(&format!(" Subtract {k}."));
                v -= k;
            }
            _ => {
                chain.push_str(&format!(" Multiply by {k}."));
                v *= k;
            }
        }
        work.push_str(&format!("{v} "));
    }
    chain.push_str(" Show each intermediate value.");
    Example {
        prompt: chain,
        answer: work.trim().to_string(),
    }
}

/// pref-syn: preference pairs (the UltraFeedback stand-in).  Chosen = the
/// correct arithmetic continuation; rejected = corrupted (wrong value or
/// garbled) — a learnable preference signal on the same loss scale as SFT,
/// matching the paper's observation that SFT/DPO detectors share
/// thresholds.
pub fn pref_syn(rng: &mut Pcg32) -> PrefExample {
    let base = gsm_syn(rng);
    let correct: i64 = base.answer.parse().unwrap();
    let rejected = match rng.range_usize(0, 2) {
        0 => (correct + rng.range_i64(1, 9)).to_string(),
        1 => (correct.saturating_sub(rng.range_i64(1, 9)).max(0)).to_string(),
        _ => format!("{correct}{}", rng.range_i64(0, 9)),
    };
    PrefExample {
        prompt: base.prompt,
        chosen: base.answer,
        rejected,
    }
}

/// Dataset registry entry: name → generator + relative difficulty profile
/// consumed by the loss-trajectory simulator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// Irreducible loss floor under the best configuration (tiny-family
    /// scale; calibrated from real runs, see EXPERIMENTS.md).
    pub loss_floor: f64,
    /// Initial loss at random-adapter init (byte vocab → ~ln(vocab_eff)).
    pub loss_init: f64,
    /// Overfit propensity multiplier (small data ⇒ higher).
    pub overfit_propensity: f64,
    /// Sequence length multiplier vs task default (OT3 uses 2×).
    pub seq_scale: f64,
}

pub const DATASETS: &[DatasetProfile] = &[
    DatasetProfile {
        name: "gsm-syn",
        loss_floor: 0.55,
        loss_init: 5.6,
        overfit_propensity: 1.0,
        seq_scale: 1.0,
    },
    DatasetProfile {
        name: "instr-syn",
        loss_floor: 0.85,
        loss_init: 5.6,
        overfit_propensity: 1.3,
        seq_scale: 1.0,
    },
    DatasetProfile {
        name: "reason-syn",
        loss_floor: 0.70,
        loss_init: 5.6,
        overfit_propensity: 1.1,
        seq_scale: 2.0,
    },
    DatasetProfile {
        name: "pref-syn",
        loss_floor: 0.45,
        loss_init: 0.6931, // DPO loss starts at ln 2
        overfit_propensity: 1.6,
        seq_scale: 1.0,
    },
];

pub fn dataset_profile(name: &str) -> Option<&'static DatasetProfile> {
    DATASETS.iter().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gsm_answers_parse_and_are_consistent() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..200 {
            let ex = gsm_syn(&mut rng);
            let v: i64 = ex.answer.parse().expect("answer must be an integer");
            assert!(v >= 0, "negative answer {v} from '{}'", ex.prompt);
            assert!(ex.prompt.contains("How many"));
        }
    }

    #[test]
    fn gsm_deterministic_per_seed() {
        let a: Vec<Example> = {
            let mut r = Pcg32::seeded(9);
            (0..20).map(|_| gsm_syn(&mut r)).collect()
        };
        let b: Vec<Example> = {
            let mut r = Pcg32::seeded(9);
            (0..20).map(|_| gsm_syn(&mut r)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn gsm_has_variety() {
        let mut rng = Pcg32::seeded(2);
        let prompts: Vec<String> = (0..50).map(|_| gsm_syn(&mut rng).prompt).collect();
        let mut uniq = prompts.clone();
        uniq.sort();
        uniq.dedup();
        assert!(uniq.len() > 30, "only {} unique prompts", uniq.len());
    }

    #[test]
    fn instr_nonempty() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..100 {
            let ex = instr_syn(&mut rng);
            assert!(!ex.prompt.is_empty() && !ex.answer.is_empty());
        }
    }

    #[test]
    fn reason_chains_longer_than_instr() {
        let mut rng = Pcg32::seeded(4);
        let r: f64 = (0..50)
            .map(|_| reason_syn(&mut rng).prompt.len() as f64)
            .sum::<f64>()
            / 50.0;
        let i: f64 = (0..50)
            .map(|_| instr_syn(&mut rng).prompt.len() as f64)
            .sum::<f64>()
            / 50.0;
        assert!(r > i, "reason {r} vs instr {i}");
    }

    #[test]
    fn pref_chosen_differs_from_rejected() {
        let mut rng = Pcg32::seeded(5);
        for _ in 0..100 {
            let p = pref_syn(&mut rng);
            assert_ne!(p.chosen, p.rejected);
        }
    }

    #[test]
    fn profiles_exist_for_all_datasets() {
        for name in ["gsm-syn", "instr-syn", "reason-syn", "pref-syn"] {
            assert!(dataset_profile(name).is_some(), "{name}");
        }
        assert!(dataset_profile("imagenet").is_none());
    }
}
