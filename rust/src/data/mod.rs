//! Synthetic workloads: the byte-level tokenizer, the four seeded
//! datasets replacing GSM8K / Tulu-3 / OpenThoughts3 / UltraFeedback, and
//! the [N, B, T] batch builders the executors feed to the AOT train step.

pub mod corpus;
pub mod synth;
pub mod tokenizer;

pub use corpus::{Batch, Corpus, Encoded, PrefBatch, PrefCorpus};
pub use synth::{dataset_profile, DatasetProfile, Example, PrefExample, DATASETS};
