//! Byte-level tokenizer, mirrored exactly by `python/compile/model.py`
//! (PAD/BOS/EOS/SEP ids and vocab size are asserted against the artifact
//! manifest at load time).

pub const PAD_ID: i32 = 256;
pub const BOS_ID: i32 = 257;
pub const EOS_ID: i32 = 258;
pub const SEP_ID: i32 = 259;
pub const VOCAB_SIZE: usize = 272;

/// Encode raw text as byte tokens (no specials).
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

/// Decode byte tokens back to text; specials are dropped.
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Build a training sequence `BOS prompt SEP answer EOS` padded/truncated
/// to `seq_len`, plus next-token targets with the prompt region masked to
/// PAD (completion-only loss, the paper's Tulu-3/OT3 metric).
///
/// Returns `(tokens, targets)` each of length `seq_len`.
pub fn encode_example(prompt: &str, answer: &str, seq_len: usize) -> (Vec<i32>, Vec<i32>) {
    // If the full sequence would overflow, truncate the *prompt* (keep its
    // tail) so the answer — the part the loss is computed on — survives.
    let ans = encode(answer);
    let budget = seq_len.saturating_sub(ans.len() + 3); // BOS + SEP + EOS
    let mut p = encode(prompt);
    if p.len() > budget {
        p.drain(..p.len() - budget);
    }
    let mut toks = Vec::with_capacity(seq_len);
    toks.push(BOS_ID);
    toks.extend(p);
    toks.push(SEP_ID);
    let answer_start = toks.len(); // first answer position
    toks.extend(ans);
    toks.push(EOS_ID);
    toks.truncate(seq_len);
    while toks.len() < seq_len {
        toks.push(PAD_ID);
    }
    // next-token targets: target[i] = toks[i+1]; mask positions whose
    // *predicted* token is still inside the prompt (i + 1 < answer_start)
    let mut targets = vec![PAD_ID; seq_len];
    for i in 0..seq_len - 1 {
        if i + 1 >= answer_start {
            targets[i] = toks[i + 1];
        }
    }
    (toks, targets)
}

/// Position where the answer begins for a given prompt (used by the
/// decode-time driver to know where to start generation).
pub fn answer_start(prompt: &str) -> usize {
    1 + prompt.len() + 1 // BOS + prompt bytes + SEP
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = encode("Q: 2+2?");
        assert_eq!(decode(&t), "Q: 2+2?");
    }

    #[test]
    fn specials_dropped_in_decode() {
        let toks = vec![BOS_ID, 65, SEP_ID, 66, EOS_ID, PAD_ID];
        assert_eq!(decode(&toks), "AB");
    }

    #[test]
    fn example_layout() {
        let (toks, targets) = encode_example("ab", "7", 10);
        assert_eq!(toks[0], BOS_ID);
        assert_eq!(&toks[1..3], &[97, 98]);
        assert_eq!(toks[3], SEP_ID);
        assert_eq!(toks[4], b'7' as i32);
        assert_eq!(toks[5], EOS_ID);
        assert_eq!(toks[6], PAD_ID);
        // prompt region masked: targets before answer are PAD
        assert_eq!(targets[0], PAD_ID);
        assert_eq!(targets[1], PAD_ID);
        assert_eq!(targets[2], PAD_ID);
        // position 3 (SEP) predicts the first answer byte
        assert_eq!(targets[3], b'7' as i32);
        assert_eq!(targets[4], EOS_ID);
        assert_eq!(targets[5], PAD_ID);
    }

    #[test]
    fn truncation_and_padding() {
        let (toks, _) = encode_example("abcdefghij", "12345", 8);
        assert_eq!(toks.len(), 8);
        let (toks2, _) = encode_example("a", "b", 16);
        assert_eq!(toks2.len(), 16);
        assert!(toks2[5..].iter().all(|&t| t == PAD_ID));
    }

    #[test]
    fn answer_start_matches_layout() {
        let (toks, _) = encode_example("xy", "9", 10);
        let s = answer_start("xy");
        assert_eq!(toks[s], b'9' as i32);
    }

    #[test]
    fn utf8_passthrough_bytes() {
        let t = encode("é"); // 2 bytes
        assert_eq!(t.len(), 2);
        assert_eq!(decode(&t), "é");
    }
}
