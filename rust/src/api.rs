//! The declarative API surface (paper Listing 1), Rust-native:
//!
//! ```no_run
//! use alto::api::{Engine, EarlyExit};
//! use alto::config::{SearchSpace, TaskSpec};
//!
//! let engine = Engine::new("adapter_parallel", 8);
//! let tasks = vec![TaskSpec {
//!     name: "math".into(),
//!     model: "llama-70b".into(),
//!     dataset: "gsm-syn".into(),
//!     num_gpus: 4,
//!     search_space: SearchSpace {
//!         lrs: vec![1e-5],
//!         ranks: vec![16],
//!         batch_sizes: vec![1, 2],
//!     },
//!     ..TaskSpec::default()
//! }];
//! let early_exit = EarlyExit::new().warmup_ratio(0.10);
//! let schedule = engine.schedule(&tasks).unwrap();
//! let best = engine.batched_execution(&tasks, early_exit).unwrap();
//! println!("{} tasks, makespan plan {:.1}s, best[0] val loss {:.3}",
//!          best.len(), schedule.makespan, best[0].best_val);
//! ```

use anyhow::Result;

use crate::cluster::gpu::GpuSpec;
use crate::config::{TaskSpec, MODEL_FAMILY};
use crate::coordinator::service::{Service, ServiceConfig, TaskOutcome};
use crate::coordinator::task_runner::RunConfig;
use crate::coordinator::Profiler;
use crate::sched::inter::Policy;
use crate::sched::solver::{self, SchedTask, Schedule};

/// Early-exit strategy builder (Listing 1's `alto.EarlyExit`).
#[derive(Debug, Clone)]
pub struct EarlyExit {
    run: RunConfig,
}

impl Default for EarlyExit {
    fn default() -> Self {
        Self::new()
    }
}

impl EarlyExit {
    pub fn new() -> EarlyExit {
        EarlyExit {
            run: RunConfig::default(),
        }
    }

    /// Fraction of total steps used as warmup (paper default 0.05).
    pub fn warmup_ratio(mut self, r: f64) -> EarlyExit {
        self.run.warmup.warmup_ratio = r;
        self
    }

    /// Fraction of candidates retained at the warmup boundary.
    pub fn select_ratio(mut self, r: f64) -> EarlyExit {
        self.run.warmup.select_ratio = r;
        self
    }

    /// Disable everything (the ablation baseline).
    pub fn disabled() -> EarlyExit {
        EarlyExit {
            run: RunConfig {
                enable_early_exit: false,
                enable_warmup_selection: false,
                ..RunConfig::default()
            },
        }
    }

    pub fn into_run_config(self) -> RunConfig {
        self.run
    }
}

/// The engine (Listing 1's `alto.Engine`).
pub struct Engine {
    pub strategy: String,
    pub total_gpus: usize,
    pub gpu: GpuSpec,
    pub n_slots: usize,
}

impl Engine {
    /// `strategy` is currently `"adapter_parallel"` (the only multi-GPU
    /// execution mode ALTO ships; baselines live in `alto::parallel`).
    pub fn new(strategy: &str, total_gpus: usize) -> Engine {
        Engine {
            strategy: strategy.to_string(),
            total_gpus,
            gpu: GpuSpec::h100_sxm5(),
            n_slots: 4,
        }
    }

    /// Plan task placement (Listing 1's `engine.schedule(tasks,
    /// method="MILP")`) — exact makespan optimization via the B&B solver.
    pub fn schedule(&self, tasks: &[TaskSpec]) -> Result<Schedule> {
        let mut profiler = Profiler::new(self.gpu.clone());
        let sched_tasks: Vec<SchedTask> = tasks
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let model = MODEL_FAMILY
                    .get(&t.model)
                    .ok_or_else(|| anyhow::anyhow!("unknown model {}", t.model))?;
                Ok(SchedTask {
                    id: i,
                    duration: profiler.estimate_duration(&model, t, self.n_slots),
                    gpus: t.num_gpus,
                })
            })
            .collect::<Result<_>>()?;
        solver::solve(&sched_tasks, self.total_gpus)
    }

    /// Execute all tasks under the hierarchical scheduler with batched
    /// multi-LoRA executors + early exit; returns per-task outcomes
    /// (best adapter config + quality + accounting).
    pub fn batched_execution(
        &self,
        tasks: &[TaskSpec],
        early_exit: EarlyExit,
    ) -> Result<Vec<TaskOutcome>> {
        let svc = Service::new(ServiceConfig {
            total_gpus: self.total_gpus,
            policy: Policy::Optimal,
            run: early_exit.into_run_config(),
            gpu: self.gpu.clone(),
            n_slots: self.n_slots,
            ..ServiceConfig::default()
        });
        Ok(svc.run_service(tasks)?.outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchSpace;

    #[test]
    fn listing_one_flow() {
        let engine = Engine::new("adapter_parallel", 8);
        let tasks = vec![TaskSpec {
            name: "math-70b".into(),
            model: "llama-70b".into(),
            dataset: "gsm-syn".into(),
            num_gpus: 4,
            search_space: SearchSpace {
                lrs: vec![1e-5],
                ranks: vec![16],
                batch_sizes: vec![1, 2],
            },
            train_samples: 64,
            ..TaskSpec::default()
        }];
        let schedule = engine.schedule(&tasks).unwrap();
        assert!(schedule.makespan > 0.0);
        let outcomes = engine
            .batched_execution(&tasks, EarlyExit::new().warmup_ratio(0.10))
            .unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].best_val.is_finite());
    }

    #[test]
    fn early_exit_builder() {
        let ee = EarlyExit::new().warmup_ratio(0.2).select_ratio(0.5);
        let rc = ee.into_run_config();
        assert_eq!(rc.warmup.warmup_ratio, 0.2);
        assert_eq!(rc.warmup.select_ratio, 0.5);
        assert!(rc.enable_early_exit);
        let off = EarlyExit::disabled().into_run_config();
        assert!(!off.enable_early_exit);
    }
}
