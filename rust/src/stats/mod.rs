//! Statistics primitives used by the early-exit detectors (EMA, OLS slope)
//! and the evaluation harness (Spearman ρ, summaries).

pub mod describe;
pub mod ema;
pub mod linreg;
pub mod spearman;

pub use describe::{argmax, argmin, mean, quantile, std_dev, summarize, Summary};
pub use ema::{ema_series, Ema};
pub use linreg::{fit_xy, slope, slope_tail};
pub use spearman::{best_in_topk, pearson, ranks, spearman, topk_coverage};
