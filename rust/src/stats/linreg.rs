//! Ordinary least-squares slope over a window — the paper's
//! `linregSlope(ℓ[-w:])` divergence detector primitive (Algorithm 1).

/// Slope of the OLS fit of `ys` against x = 0..n-1.
/// Returns 0.0 for fewer than 2 points (no trend information).
pub fn slope(ys: &[f64]) -> f64 {
    let n = ys.len();
    if n < 2 {
        return 0.0;
    }
    let nf = n as f64;
    let mean_x = (nf - 1.0) / 2.0;
    let mean_y = ys.iter().sum::<f64>() / nf;
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &y) in ys.iter().enumerate() {
        let dx = i as f64 - mean_x;
        num += dx * (y - mean_y);
        den += dx * dx;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Slope over the most recent `w` values (`linregSlope(xs[-w:])`).
pub fn slope_tail(ys: &[f64], w: usize) -> f64 {
    let start = ys.len().saturating_sub(w);
    slope(&ys[start..])
}

/// Full OLS fit y = a + b·x over arbitrary x — used by the memory model
/// M̂(B) = k0 + k1·B·L (paper §A.3).  Returns (intercept, slope).
pub fn fit_xy(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let b = if den == 0.0 { 0.0 } else { num / den };
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line() {
        let ys: Vec<f64> = (0..10).map(|i| 3.0 + 2.0 * i as f64).collect();
        assert!((slope(&ys) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn negative_slope() {
        let ys: Vec<f64> = (0..5).map(|i| 10.0 - 0.5 * i as f64).collect();
        assert!((slope(&ys) + 0.5).abs() < 1e-12);
    }

    #[test]
    fn constant_series_zero() {
        assert_eq!(slope(&[4.0; 8]), 0.0);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(slope(&[]), 0.0);
        assert_eq!(slope(&[1.0]), 0.0);
    }

    #[test]
    fn tail_window() {
        // flat then rising: tail slope over last 3 sees the rise
        let ys = [1.0, 1.0, 1.0, 1.0, 2.0, 3.0, 4.0];
        assert!(slope_tail(&ys, 3) > 0.9);
        assert!(slope(&ys) > 0.0);
        // window larger than series = full series
        assert_eq!(slope_tail(&ys, 100), slope(&ys));
    }

    #[test]
    fn fit_xy_recovers_line() {
        let xs: Vec<f64> = vec![1.0, 2.0, 4.0, 8.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.5 + 3.0 * x).collect();
        let (a, b) = fit_xy(&xs, &ys);
        assert!((a - 0.5).abs() < 1e-9);
        assert!((b - 3.0).abs() < 1e-9);
    }

    #[test]
    fn fit_xy_noise_robust() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        // deterministic "noise"
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 2.0 + 0.1 * x + 0.01 * (x * 7.0).sin())
            .collect();
        let (a, b) = fit_xy(&xs, &ys);
        assert!((b - 0.1).abs() < 1e-3, "b={b}");
        assert!((a - 2.0).abs() < 0.05, "a={a}");
    }
}
