//! Spearman rank correlation (ties handled by average ranks) — used for
//! the warmup-vs-final loss correlation analysis (paper Fig. 7 / Fig. 16).

/// Average ranks (1-based); ties share the mean of their positions.
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            out[k] = avg;
        }
        i = j + 1;
    }
    out
}

/// Pearson correlation.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Spearman ρ = Pearson over average ranks.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// Fraction of the true bottom-q quantile (by `final_vals`, lower=better)
/// that is captured by the predicted bottom-q (by `early_vals`) — the
/// paper's "top-25% coverage" metric (Fig. 16 middle).
pub fn topk_coverage(early_vals: &[f64], final_vals: &[f64], q: f64) -> f64 {
    let n = early_vals.len();
    if n == 0 {
        return 0.0;
    }
    let k = ((n as f64 * q).ceil() as usize).clamp(1, n);
    let bottom = |vals: &[f64]| -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
        idx.truncate(k);
        idx
    };
    let pred = bottom(early_vals);
    let truth = bottom(final_vals);
    let hit = truth.iter().filter(|t| pred.contains(t)).count();
    hit as f64 / k as f64
}

/// Whether the single best (lowest final) config is inside the predicted
/// bottom-q set (Fig. 16 right).
pub fn best_in_topk(early_vals: &[f64], final_vals: &[f64], q: f64) -> bool {
    let n = final_vals.len();
    if n == 0 {
        return false;
    }
    let k = ((n as f64 * q).ceil() as usize).clamp(1, n);
    let best = final_vals
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| early_vals[a].partial_cmp(&early_vals[b]).unwrap());
    idx[..k].contains(&best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_monotone() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [10.0, 20.0, 30.0, 40.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
        let yrev = [40.0, 30.0, 20.0, 10.0];
        assert!((spearman(&x, &yrev) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn nonlinear_monotone_still_one() {
        let x: [f64; 5] = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y: Vec<f64> = x.iter().map(|v| v.exp()).collect();
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ties_average() {
        let r = ranks(&[3.0, 1.0, 3.0]);
        assert_eq!(r, vec![2.5, 1.0, 2.5]);
    }

    #[test]
    fn independent_near_zero() {
        let x: Vec<f64> = (0..200).map(|i| ((i * 37) % 101) as f64).collect();
        let y: Vec<f64> = (0..200).map(|i| ((i * 53) % 97) as f64).collect();
        assert!(spearman(&x, &y).abs() < 0.2);
    }

    #[test]
    fn coverage_perfect_predictor() {
        let fin = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
        assert_eq!(topk_coverage(&fin, &fin, 0.25), 1.0);
        assert!(best_in_topk(&fin, &fin, 0.25));
    }

    #[test]
    fn coverage_inverted_predictor() {
        let fin = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
        let early: Vec<f64> = fin.iter().map(|v| -v).collect();
        assert_eq!(topk_coverage(&early, &fin, 0.25), 0.0);
        assert!(!best_in_topk(&early, &fin, 0.25));
    }

    #[test]
    fn constant_series_zero_rho() {
        assert_eq!(spearman(&[1.0; 5], &[1.0, 2.0, 3.0, 4.0, 5.0]), 0.0);
    }
}
