//! Descriptive statistics for experiment reporting (mean/std/quantiles),
//! replacing scipy/numpy on the Rust side.

#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub max: f64,
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n−1 denominator).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Linear-interpolated quantile, q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn summarize(xs: &[f64]) -> Summary {
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: std_dev(xs),
        min: quantile(xs, 0.0),
        p25: quantile(xs, 0.25),
        median: quantile(xs, 0.5),
        p75: quantile(xs, 0.75),
        max: quantile(xs, 1.0),
    }
}

pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

pub fn argmax(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_is_consistent() {
        let xs: Vec<f64> = (1..=101).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert_eq!(s.n, 101);
        assert_eq!(s.median, 51.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 101.0);
        assert_eq!(s.p25, 26.0);
        assert_eq!(s.p75, 76.0);
    }

    #[test]
    fn arg_extrema() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(argmin(&xs), Some(1));
        assert_eq!(argmax(&xs), Some(0));
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn empty_and_singleton() {
        assert!(mean(&[]).is_nan());
        assert_eq!(std_dev(&[5.0]), 0.0);
        assert_eq!(quantile(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn nan_skipped_in_argmin() {
        let xs = [f64::NAN, 2.0, 1.0];
        assert_eq!(argmin(&xs), Some(2));
    }
}
