//! Exponential moving average — the paper's training-loss smoother
//! (§5.1): `ℓ̂_t = α·ℓ_t + (1−α)·ℓ̂_{t−1}`.

/// Streaming EMA.  The first observation initializes the average.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        assert!(
            (0.0..=1.0).contains(&alpha) && alpha > 0.0,
            "alpha must be in (0, 1], got {alpha}"
        );
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

/// Smooth a whole series (used when replaying stored loss histories).
pub fn ema_series(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut e = Ema::new(alpha);
    xs.iter().map(|&x| e.update(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_value_passthrough() {
        let mut e = Ema::new(0.3);
        assert_eq!(e.update(5.0), 5.0);
    }

    #[test]
    fn recurrence_matches_paper_formula() {
        let mut e = Ema::new(0.25);
        e.update(4.0);
        let v = e.update(8.0);
        assert!((v - (0.25 * 8.0 + 0.75 * 4.0)).abs() < 1e-12);
    }

    #[test]
    fn alpha_one_tracks_input() {
        let mut e = Ema::new(1.0);
        e.update(1.0);
        assert_eq!(e.update(9.0), 9.0);
    }

    #[test]
    fn converges_to_constant() {
        let mut e = Ema::new(0.2);
        for _ in 0..200 {
            e.update(3.0);
        }
        assert!((e.value().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn series_matches_streaming() {
        let xs = [1.0, 2.0, 0.5, 3.0];
        let s = ema_series(&xs, 0.4);
        let mut e = Ema::new(0.4);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(s[i], e.update(x));
        }
    }

    #[test]
    #[should_panic]
    fn rejects_zero_alpha() {
        Ema::new(0.0);
    }
}
