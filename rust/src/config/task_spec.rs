//! Task specification — what a tenant submits to the service
//! (paper Listing 1: base model, dataset, search space, GPU count).

use crate::util::intern::Istr;
use crate::util::json::Json;

use super::search::SearchSpace;

/// Training objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Supervised fine-tuning (causal LM cross-entropy).
    Sft,
    /// Direct preference optimization.
    Dpo,
}

impl Objective {
    pub fn as_str(&self) -> &'static str {
        match self {
            Objective::Sft => "sft",
            Objective::Dpo => "dpo",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Objective> {
        match s {
            "sft" => Ok(Objective::Sft),
            "dpo" => Ok(Objective::Dpo),
            other => anyhow::bail!("unknown objective '{other}'"),
        }
    }
}

/// A LoRA fine-tuning task: one (model, dataset, search space) triple that
/// expands into `search_space.len()` jobs.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    pub name: String,
    /// Model-family identity, interned: a 1M-task trace over a small
    /// family shares one allocation per distinct name, and cloning the
    /// spec (or keying a map on the family) never copies the text.
    pub model: Istr,
    /// Dataset identity, interned like [`TaskSpec::model`].
    pub dataset: Istr,
    pub objective: Objective,
    pub search_space: SearchSpace,
    pub epochs: usize,
    pub num_gpus: usize,
    pub seq_len: usize,
    /// Training-set size in samples (drives the duration estimate d_i).
    pub train_samples: usize,
    pub seed: u64,
    /// Scheduling priority (higher wins; only consulted when the harness
    /// runs with preemption-on-arrival enabled).  Defaults to 0.
    pub priority: i64,
    /// Submitting tenant.  Empty ("", the default) means untagged: all
    /// untagged tasks share one admission pool.  Only consulted by
    /// overload control (weighted queue shares under pressure); the
    /// task *body* is tenant-blind.
    pub tenant: String,
    /// This tenant's fair-share weight for admission control (1.0 = one
    /// share).  Higher-weight tenants keep proportionally more of the
    /// waiting queue under pressure.
    pub tenant_weight: f64,
    /// SLO deadline in seconds *after arrival*; 0.0 (the default) means
    /// none.  Under overload control, a queued task that can no longer
    /// meet its deadline even if started immediately is shed.
    pub slo_deadline: f64,
}

impl TaskSpec {
    pub fn num_jobs(&self) -> usize {
        self.search_space.len()
    }

    /// Total samples the naive grid search would consume (all jobs × all
    /// epochs) — the denominator of the paper's "samples saved" metric.
    pub fn total_samples(&self) -> usize {
        self.num_jobs() * self.epochs * self.train_samples
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("model", Json::Str(self.model.to_string())),
            ("dataset", Json::Str(self.dataset.to_string())),
            ("objective", Json::Str(self.objective.as_str().into())),
            ("search_space", self.search_space.to_json()),
            ("epochs", Json::Num(self.epochs as f64)),
            ("num_gpus", Json::Num(self.num_gpus as f64)),
            ("seq_len", Json::Num(self.seq_len as f64)),
            ("train_samples", Json::Num(self.train_samples as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("priority", Json::Num(self.priority as f64)),
        ];
        // admission-control fields appear only when set, so pre-existing
        // spec files round-trip byte-identically
        if !self.tenant.is_empty() {
            fields.push(("tenant", Json::Str(self.tenant.clone())));
        }
        if self.tenant_weight != 1.0 {
            fields.push(("tenant_weight", Json::Num(self.tenant_weight)));
        }
        if self.slo_deadline != 0.0 {
            fields.push(("slo_deadline", Json::Num(self.slo_deadline)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TaskSpec> {
        let s = |key: &str| -> anyhow::Result<String> {
            Ok(j.req(key)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("{key} not a string"))?
                .to_string())
        };
        let u = |key: &str, default: usize| -> usize {
            j.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
        };
        Ok(TaskSpec {
            name: s("name")?,
            model: s("model")?.into(),
            dataset: s("dataset")?.into(),
            objective: Objective::parse(
                j.get("objective").and_then(|v| v.as_str()).unwrap_or("sft"),
            )?,
            search_space: SearchSpace::from_json(j.req("search_space")?)?,
            epochs: u("epochs", 3),
            num_gpus: u("num_gpus", 1),
            seq_len: u("seq_len", 64),
            train_samples: u("train_samples", 1024),
            seed: j.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
            priority: j.get("priority").and_then(|v| v.as_i64()).unwrap_or(0),
            tenant: j
                .get("tenant")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            tenant_weight: j
                .get("tenant_weight")
                .and_then(|v| v.as_f64())
                .unwrap_or(1.0),
            slo_deadline: j
                .get("slo_deadline")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
        })
    }

    /// Parse a file containing either one task object or an array of them.
    pub fn load_file(path: &str) -> anyhow::Result<Vec<TaskSpec>> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        match &j {
            Json::Arr(items) => items.iter().map(TaskSpec::from_json).collect(),
            _ => Ok(vec![TaskSpec::from_json(&j)?]),
        }
    }
}

impl Default for TaskSpec {
    fn default() -> Self {
        TaskSpec {
            name: "task".into(),
            model: "nano".into(),
            dataset: "gsm-syn".into(),
            objective: Objective::Sft,
            search_space: SearchSpace::tiny_sweep(),
            epochs: 3,
            num_gpus: 1,
            seq_len: 32,
            train_samples: 1024,
            seed: 0,
            priority: 0,
            tenant: String::new(),
            tenant_weight: 1.0,
            slo_deadline: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let t = TaskSpec {
            name: "math".into(),
            model: "micro".into(),
            dataset: "gsm-syn".into(),
            objective: Objective::Dpo,
            search_space: SearchSpace::paper_single_gpu(),
            epochs: 3,
            num_gpus: 4,
            seq_len: 128,
            train_samples: 9000,
            seed: 7,
            priority: 2,
            tenant: "acme".into(),
            tenant_weight: 2.5,
            slo_deadline: 1800.0,
        };
        let j = Json::parse(&t.to_json().to_string()).unwrap();
        assert_eq!(TaskSpec::from_json(&j).unwrap(), t);
        // default admission fields stay off the wire entirely
        let plain = TaskSpec::default().to_json().to_string();
        for key in ["tenant", "tenant_weight", "slo_deadline"] {
            assert!(!plain.contains(key), "default spec leaked '{key}': {plain}");
        }
        let j = Json::parse(&plain).unwrap();
        assert_eq!(TaskSpec::from_json(&j).unwrap(), TaskSpec::default());
    }

    #[test]
    fn totals() {
        let t = TaskSpec {
            epochs: 3,
            train_samples: 100,
            ..Default::default()
        };
        assert_eq!(t.num_jobs(), t.search_space.len());
        assert_eq!(t.total_samples(), t.num_jobs() * 300);
    }

    #[test]
    fn objective_parse() {
        assert_eq!(Objective::parse("sft").unwrap(), Objective::Sft);
        assert_eq!(Objective::parse("dpo").unwrap(), Objective::Dpo);
        assert!(Objective::parse("ppo").is_err());
    }
}
