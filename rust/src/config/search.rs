//! Hyperparameter configurations and search spaces.
//!
//! A LoRA *task* owns a search space; each point in it is one *job*
//! (paper §1: "a LoRA fine-tuning job = training under a specific
//! hyperparameter configuration").

use crate::util::json::Json;

/// One hyperparameter configuration = one job's settings.
#[derive(Debug, Clone, PartialEq)]
pub struct HyperParams {
    pub lr: f64,
    pub rank: usize,
    pub batch_size: usize,
}

impl HyperParams {
    pub fn label(&self) -> String {
        format!("lr{:.0e}_r{}_b{}", self.lr, self.rank, self.batch_size)
    }
}

/// Grid search space (paper §A.4).
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSpace {
    pub lrs: Vec<f64>,
    pub ranks: Vec<usize>,
    pub batch_sizes: Vec<usize>,
}

impl SearchSpace {
    /// Paper's single-GPU (7B–8B) space: 5 lrs × 3 ranks × 4 batch sizes
    /// = 60 configurations.
    pub fn paper_single_gpu() -> SearchSpace {
        SearchSpace {
            lrs: vec![1e-5, 5e-5, 2e-4, 3e-4, 5e-4],
            ranks: vec![16, 32, 64],
            batch_sizes: vec![1, 2, 4, 8],
        }
    }

    /// Paper's multi-GPU (32B–70B) space: 4 × 4 × 4 = 64 configurations.
    pub fn paper_multi_gpu() -> SearchSpace {
        SearchSpace {
            lrs: vec![1e-5, 5e-5, 1e-4, 3e-4],
            ranks: vec![16, 32, 64, 128],
            batch_sizes: vec![1, 2, 4, 8],
        }
    }

    /// Scaled-down space for real CPU-PJRT sweeps (same structure,
    /// laptop-scale lrs adapted to the tiny family).
    pub fn tiny_sweep() -> SearchSpace {
        SearchSpace {
            lrs: vec![1e-4, 5e-4, 2e-3, 5e-3, 2e-2],
            ranks: vec![2, 4, 8],
            batch_sizes: vec![1, 2, 4, 8],
        }
    }

    pub fn len(&self) -> usize {
        self.lrs.len() * self.ranks.len() * self.batch_sizes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Full grid expansion, batch-size-major so homogeneous batch groups
    /// (paper §A.1) are contiguous.
    pub fn expand(&self) -> Vec<HyperParams> {
        let mut out = Vec::with_capacity(self.len());
        for &batch_size in &self.batch_sizes {
            for &rank in &self.ranks {
                for &lr in &self.lrs {
                    out.push(HyperParams {
                        lr,
                        rank,
                        batch_size,
                    });
                }
            }
        }
        out
    }

    pub fn max_rank(&self) -> usize {
        self.ranks.iter().copied().max().unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lr", Json::arr_f64(&self.lrs)),
            (
                "rank",
                Json::Arr(self.ranks.iter().map(|&r| Json::Num(r as f64)).collect()),
            ),
            (
                "batch_size",
                Json::Arr(
                    self.batch_sizes
                        .iter()
                        .map(|&b| Json::Num(b as f64))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SearchSpace> {
        let nums = |key: &str| -> anyhow::Result<Vec<f64>> {
            Ok(j.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("{key} not an array"))?
                .iter()
                .filter_map(|v| v.as_f64())
                .collect())
        };
        Ok(SearchSpace {
            lrs: nums("lr")?,
            ranks: nums("rank")?.into_iter().map(|v| v as usize).collect(),
            batch_sizes: nums("batch_size")?
                .into_iter()
                .map(|v| v as usize)
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_spaces_have_paper_sizes() {
        assert_eq!(SearchSpace::paper_single_gpu().len(), 60);
        assert_eq!(SearchSpace::paper_multi_gpu().len(), 64);
    }

    #[test]
    fn expand_covers_grid_batch_major() {
        let s = SearchSpace {
            lrs: vec![1e-4, 1e-3],
            ranks: vec![4, 8],
            batch_sizes: vec![1, 2],
        };
        let grid = s.expand();
        assert_eq!(grid.len(), 8);
        // batch-size-major: first half all bs=1
        assert!(grid[..4].iter().all(|h| h.batch_size == 1));
        assert!(grid[4..].iter().all(|h| h.batch_size == 2));
        // all distinct
        for i in 0..grid.len() {
            for j in i + 1..grid.len() {
                assert_ne!(grid[i], grid[j]);
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let s = SearchSpace::paper_single_gpu();
        let j = s.to_json();
        let s2 = SearchSpace::from_json(&Json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn label_is_readable() {
        let h = HyperParams {
            lr: 2e-4,
            rank: 16,
            batch_size: 4,
        };
        assert_eq!(h.label(), "lr2e-4_r16_b4");
    }
}
