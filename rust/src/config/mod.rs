//! Model shapes, hyperparameter search spaces and task specifications —
//! the declarative surface a user submits to the engine (paper Listing 1).

pub mod model_shape;
pub mod search;
pub mod task_spec;

pub use model_shape::{ModelShape, MODEL_FAMILY};
pub use search::{HyperParams, SearchSpace};
pub use task_spec::{Objective, TaskSpec};
