//! Backbone model shapes — the Rust mirror of `python/compile/model.py`'s
//! `ModelConfig` plus the analytic FLOP/byte accounting the cluster
//! simulator and parallelism cost models consume.
//!
//! The family replaces Llama-3.1-8B/70B and Qwen2.5-7B/32B at laptop scale
//! (DESIGN.md §3); the *simulated* H100 experiments additionally use the
//! paper's original model sizes, which are pure arithmetic here.

use crate::util::intern::Istr;

/// Shape of a TinyLlama-family backbone (or a simulated big model).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelShape {
    /// Interned family name: cloning a shape (or lifting its name into
    /// a shared-executor group key) is a reference-count bump, never a
    /// text copy — the scheduler does this per start/adopt decision.
    pub name: Istr,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
}

impl ModelShape {
    pub fn new(
        name: &str,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        d_ff: usize,
        vocab: usize,
    ) -> ModelShape {
        ModelShape {
            name: name.into(),
            d_model,
            n_layers,
            n_heads,
            d_ff,
            vocab,
        }
    }

    /// Frozen-backbone parameter count (matches model.py param_count()).
    pub fn param_count(&self) -> usize {
        let (d, f, l) = (self.d_model, self.d_ff, self.n_layers);
        let per_layer = 4 * d * d + 3 * d * f + 2 * d;
        self.vocab * d + l * per_layer + d
    }

    /// Trainable LoRA parameters for one adapter of rank `r` on all 7
    /// projections (q,k,v,o: d→d; gate,up: d→f; down: f→d).
    pub fn lora_param_count(&self, r: usize) -> usize {
        let (d, f, l) = (self.d_model, self.d_ff, self.n_layers);
        let attn = 4 * (d * r + r * d);
        let mlp = 2 * (d * r + r * f) + (f * r + r * d);
        l * (attn + mlp)
    }

    /// Dense-path FLOPs for one token, forward only (≈ 2 · params_matmul).
    pub fn flops_per_token_fwd(&self) -> f64 {
        let (d, f, l) = (self.d_model as f64, self.d_ff as f64, self.n_layers as f64);
        let attn_proj = 4.0 * 2.0 * d * d;
        let mlp = 2.0 * 3.0 * d * f;
        let head = 2.0 * self.vocab as f64 * d;
        l * (attn_proj + mlp) + head
    }

    /// fwd + bwd ≈ 3× forward (activations + weight grads), the standard
    /// 6·params·tokens rule; LoRA-only training skips base weight grads so
    /// the backward over the frozen path is ~2× fwd (dX only).
    pub fn flops_per_token_train_lora(&self) -> f64 {
        3.0 * self.flops_per_token_fwd()
    }

    /// LoRA-path FLOPs per token per adapter at rank r (fwd; shrink+expand
    /// over 7 projections).
    pub fn lora_flops_per_token_fwd(&self, r: usize) -> f64 {
        2.0 * self.lora_param_count(r) as f64 / self.n_layers as f64
            * self.n_layers as f64
    }

    /// Bytes of base weights streamed HBM→SRAM for one forward pass
    /// (each weight read once), fp16/bf16.
    pub fn base_weight_bytes(&self) -> f64 {
        2.0 * self.param_count() as f64
    }

    /// Bytes of one adapter's weights (read per pass on each rank that
    /// hosts it — the redundancy AP eliminates), fp16.
    pub fn lora_weight_bytes(&self, r: usize) -> f64 {
        2.0 * self.lora_param_count(r) as f64
    }
}

/// The real (runnable) family — must match model.py MODEL_FAMILY.
pub fn model_family() -> Vec<ModelShape> {
    vec![
        ModelShape::new("nano", 64, 2, 4, 176, 272),
        ModelShape::new("micro", 128, 4, 4, 352, 272),
        ModelShape::new("small", 256, 6, 8, 704, 272),
        ModelShape::new("medium", 512, 8, 8, 1408, 272),
        ModelShape::new("base100m", 768, 12, 12, 2112, 272),
    ]
}

/// Paper-scale shapes used only inside the cluster simulator
/// (Fig 9 / 12 / 13 — pure arithmetic, never executed).
pub fn paper_scale_family() -> Vec<ModelShape> {
    vec![
        // (name, d, L, H, d_ff, vocab) per the public model cards
        ModelShape::new("llama-1b", 2048, 16, 32, 8192, 128256),
        ModelShape::new("llama-8b", 4096, 32, 32, 14336, 128256),
        ModelShape::new("qwen-7b", 3584, 28, 28, 18944, 152064),
        ModelShape::new("qwen-32b", 5120, 64, 40, 27648, 152064),
        ModelShape::new("llama-70b", 8192, 80, 64, 28672, 128256),
    ]
}

pub struct ModelFamily;
pub static MODEL_FAMILY: ModelFamily = ModelFamily;

impl ModelFamily {
    pub fn get(&self, name: &str) -> Option<ModelShape> {
        model_family()
            .into_iter()
            .chain(paper_scale_family())
            .find(|m| m.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_python_formula() {
        let nano = MODEL_FAMILY.get("nano").unwrap();
        // vocab*d + L*(4d² + 3df + 2d) + d
        let expect = 272 * 64 + 2 * (4 * 64 * 64 + 3 * 64 * 176 + 2 * 64) + 64;
        assert_eq!(nano.param_count(), expect);
    }

    #[test]
    fn base100m_is_about_100m() {
        let m = MODEL_FAMILY.get("base100m").unwrap();
        let p = m.param_count();
        assert!(p > 80_000_000 && p < 120_000_000, "params {p}");
    }

    #[test]
    fn llama8b_is_about_8b() {
        let m = MODEL_FAMILY.get("llama-8b").unwrap();
        let p = m.param_count();
        assert!(p > 6_000_000_000 && p < 9_000_000_000, "params {p}");
    }

    #[test]
    fn lora_fraction_below_one_percent_at_paper_scale() {
        // the paper's "<1% additional parameters" claim, checked on the
        // simulated 8B shape with rank 16
        let m = MODEL_FAMILY.get("llama-8b").unwrap();
        let frac = m.lora_param_count(16) as f64 / m.param_count() as f64;
        assert!(frac < 0.01, "fraction {frac}");
    }

    #[test]
    fn lora_params_scale_linearly_in_rank() {
        let m = MODEL_FAMILY.get("small").unwrap();
        assert_eq!(m.lora_param_count(32), 2 * m.lora_param_count(16));
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(MODEL_FAMILY.get("gpt-5").is_none());
    }
}
