//! Workload description + shared cost primitives for strategy models.

use crate::cluster::gpu::GpuSpec;
use crate::config::ModelShape;

/// A multi-LoRA training workload: N adapters over one frozen backbone.
#[derive(Debug, Clone)]
pub struct Workload {
    pub model: ModelShape,
    pub ranks: Vec<usize>,
    pub batch_per_adapter: usize,
    pub seq_len: usize,
}

impl Workload {
    pub fn n_adapters(&self) -> usize {
        self.ranks.len()
    }

    pub fn tokens_per_adapter(&self) -> f64 {
        (self.batch_per_adapter * self.seq_len) as f64
    }

    pub fn total_tokens(&self) -> f64 {
        self.tokens_per_adapter() * self.n_adapters() as f64
    }
}

/// Step-time decomposition (seconds).  `total` is the critical path:
/// compute and memory overlap inside the roofline; communication, launch
/// overhead and pipeline bubbles serialize on top.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepBreakdown {
    pub compute_s: f64,
    pub memory_s: f64,
    /// LoRA-path roofline time (serializes with the base path per layer).
    pub lora_s: f64,
    pub comm_s: f64,
    pub launch_s: f64,
    pub bubble_s: f64,
    /// Fraction of rank-steps spent idle (FSDP with global batch < P).
    pub idle_frac: f64,
}

impl StepBreakdown {
    pub fn total(&self) -> f64 {
        self.compute_s.max(self.memory_s)
            + self.lora_s
            + self.comm_s
            + self.launch_s
            + self.bubble_s
    }
}

/// A parallel-execution strategy: time to advance every adapter one step.
pub trait Strategy {
    fn name(&self) -> &'static str;
    fn step_time(&self, w: &Workload, gpu: &GpuSpec, p: usize) -> StepBreakdown;

    /// Samples/second the strategy sustains on this workload.
    fn throughput(&self, w: &Workload, gpu: &GpuSpec, p: usize) -> f64 {
        let t = self.step_time(w, gpu, p).total();
        (w.n_adapters() * w.batch_per_adapter) as f64 / t
    }
}

// --- shared cost primitives -------------------------------------------------

/// Dense backbone fwd+bwd(dX-only) compute time over `tokens`, split
/// across `p` ranks.  LoRA training skips base weight grads, so backward
/// through the frozen path is ~2× forward ⇒ 3× forward total.
pub fn base_compute_time(
    model: &ModelShape,
    gpu: &GpuSpec,
    tokens: f64,
    p: usize,
    efficiency: f64,
) -> f64 {
    let flops = 3.0 * model.flops_per_token_fwd() * tokens;
    flops / (gpu.peak_flops * efficiency.max(1e-6)) / p.max(1) as f64
}

/// HBM time to stream the (possibly sharded) base weights for fwd + bwd.
/// Weights are read once per pass from this rank's HBM; `reads` counts
/// passes (fwd + bwd ⇒ 2; re-materialization adds more).
pub fn base_weight_stream_time(model: &ModelShape, gpu: &GpuSpec, p: usize, reads: f64) -> f64 {
    reads * model.base_weight_bytes() / p.max(1) as f64 / gpu.hbm_bw
}

/// How the LoRA path is executed — determines launch structure, device
/// occupancy and FLOP waste (paper §6.1's three-way comparison).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoraExec {
    /// ALTO: one grouped kernel; thread blocks concatenate across adapters
    /// (full occupancy), only diagonal blocks computed (zero FLOP waste).
    Grouped,
    /// mLoRA / PyTorch back-to-back: one kernel per adapter per GEMM —
    /// each too small to fill the device or saturate HBM.
    PerAdapter { bw_eff: f64 },
    /// LoRAFusion wide GEMM: single kernel but (ΣL_i)(Σr_i) FLOPs.
    WideFused,
}

/// LoRA-path FLOPs for one adapter: shrink+expand fwd (2·params·tok),
/// backward input grads and weight grads each the same again ⇒ 6·params·tok.
pub fn lora_flops(model: &ModelShape, rank: usize, tokens: f64) -> f64 {
    6.0 * model.lora_param_count(rank) as f64 * tokens
}

/// LoRA-path HBM bytes for one adapter: A/B weights ×3 passes ×replication
/// plus activation traffic (X in, Y out, S cache in/out per projection).
pub fn lora_bytes(model: &ModelShape, rank: usize, tokens: f64, replication: f64) -> f64 {
    let weights = 3.0 * model.lora_weight_bytes(rank) * replication;
    // per token per layer: q,k,v,o (d+d each) + gate,up (d+f) + down (f+d)
    // = 11d + 3f, plus 2r per projection for the cached S
    let (d, f) = (model.d_model as f64, model.d_ff as f64);
    let act_per_tok = (11.0 * d + 3.0 * f + 14.0 * rank as f64) * 2.0;
    weights + 3.0 * act_per_tok * model.n_layers as f64 * tokens
}

/// Roofline time of the whole LoRA path for a set of co-resident adapters.
pub fn lora_path_time(
    model: &ModelShape,
    gpu: &GpuSpec,
    ranks: &[usize],
    tokens_per_adapter: f64,
    exec: LoraExec,
    replication: f64,
) -> f64 {
    match exec {
        LoraExec::Grouped => {
            // thread blocks concatenate across adapters → occupancy from
            // the union of tiles
            let tiles: f64 = ranks.len() as f64 * (tokens_per_adapter / 128.0).ceil();
            let eff = (tiles / gpu.sm_count as f64).min(1.0).max(0.02);
            let flops: f64 = ranks
                .iter()
                .map(|&r| lora_flops(model, r, tokens_per_adapter))
                .sum();
            let bytes: f64 = ranks
                .iter()
                .map(|&r| lora_bytes(model, r, tokens_per_adapter, replication))
                .sum();
            (flops / (gpu.peak_flops * eff)).max(bytes / gpu.hbm_bw)
        }
        LoraExec::PerAdapter { bw_eff } => ranks
            .iter()
            .map(|&r| {
                let tiles = (tokens_per_adapter / 128.0).ceil();
                let eff = (tiles / gpu.sm_count as f64).min(1.0).max(0.02);
                let flops = lora_flops(model, r, tokens_per_adapter);
                let bytes = lora_bytes(model, r, tokens_per_adapter, replication);
                (flops / (gpu.peak_flops * eff)).max(bytes / (gpu.hbm_bw * bw_eff))
            })
            .sum(),
        LoraExec::WideFused => {
            let n = ranks.len() as f64;
            let tiles: f64 = n * (tokens_per_adapter / 128.0).ceil();
            let eff = (tiles / gpu.sm_count as f64).min(1.0).max(0.02);
            // every token multiplies against every adapter's columns
            let flops: f64 = ranks
                .iter()
                .map(|&r| lora_flops(model, r, tokens_per_adapter) * n)
                .sum();
            let bytes: f64 = ranks
                .iter()
                .map(|&r| lora_bytes(model, r, tokens_per_adapter, replication))
                .sum();
            (flops / (gpu.peak_flops * eff)).max(bytes / gpu.hbm_bw)
        }
    }
}

/// Activation HBM traffic per token (reads+writes through the layers) —
/// matters at tiny batch where weight streaming dominates anyway; a fixed
/// small coefficient keeps the model simple.
pub fn activation_stream_time(model: &ModelShape, gpu: &GpuSpec, tokens: f64, p: usize) -> f64 {
    let bytes_per_tok = 2.0 * 8.0 * model.d_model as f64 * model.n_layers as f64;
    bytes_per_tok * tokens / p.max(1) as f64 / gpu.hbm_bw
}

/// GEMM efficiency from tile occupancy: an (M × N_out) GEMM decomposes
/// into ⌈M/128⌉·⌈N_out/128⌉ MXU/tensor-core tiles; the device saturates
/// once there is at least one tile per SM.  Small-batch GEMMs underfill
/// the device — the Fig 4 SM-occupancy effect.
pub fn gemm_efficiency(m_rows: f64, n_cols: f64, gpu: &GpuSpec) -> f64 {
    let tiles = (m_rows / 128.0).ceil().max(1.0) * (n_cols / 128.0).ceil().max(1.0);
    (tiles / gpu.sm_count as f64).min(1.0).max(0.02)
}

/// Efficiency of the backbone GEMMs at a given token count (output width
/// = d_model, the dominant projection shape).
pub fn base_gemm_efficiency(model: &ModelShape, tokens: f64, gpu: &GpuSpec) -> f64 {
    gemm_efficiency(tokens, model.d_model as f64, gpu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MODEL_FAMILY;

    fn w8() -> Workload {
        Workload {
            model: MODEL_FAMILY.get("llama-8b").unwrap(),
            ranks: vec![16; 8],
            batch_per_adapter: 2,
            seq_len: 256,
        }
    }

    #[test]
    fn workload_totals() {
        let w = w8();
        assert_eq!(w.n_adapters(), 8);
        assert_eq!(w.tokens_per_adapter(), 512.0);
        assert_eq!(w.total_tokens(), 4096.0);
    }

    #[test]
    fn lora_path_memory_bound_base_compute_bound() {
        // the paper's central asymmetry (§6.1): the base GEMM has high
        // arithmetic intensity (compute-bound at scale) while the LoRA
        // kernels sit far below the machine balance (bandwidth-bound)
        let w = w8();
        let g = GpuSpec::h100_sxm5();
        let balance = g.peak_flops / g.hbm_bw;
        let lora_ai = lora_flops(&w.model, 16, 512.0)
            / lora_bytes(&w.model, 16, 512.0, 1.0);
        assert!(lora_ai < balance, "LoRA AI {lora_ai} vs balance {balance}");
        // grouped path bounded by bytes, not flops, at full occupancy
        let t = lora_path_time(&w.model, &g, &w.ranks, 512.0, LoraExec::Grouped, 1.0);
        let bytes: f64 = w.ranks.iter().map(|&r| lora_bytes(&w.model, r, 512.0, 1.0)).sum();
        assert!((t - bytes / g.hbm_bw).abs() / t < 0.5, "should be ~memory-bound");
        let base_c = base_compute_time(&w.model, &g, w.total_tokens(), 1, 1.0);
        let base_m = base_weight_stream_time(&w.model, &g, 1, 2.0);
        assert!(base_c > 0.0 && base_m > 0.0);
    }

    #[test]
    fn grouped_faster_than_per_adapter_and_wide() {
        // §6.1: grouped beats 3N-launch per-adapter execution AND the
        // wide-GEMM fused formulation
        let g = GpuSpec::h100_sxm5();
        let m = MODEL_FAMILY.get("llama-1b").unwrap();
        let ranks = vec![16usize; 32];
        let grouped = lora_path_time(&m, &g, &ranks, 256.0, LoraExec::Grouped, 1.0);
        let per = lora_path_time(&m, &g, &ranks, 256.0,
                                 LoraExec::PerAdapter { bw_eff: 0.5 }, 1.0);
        let wide = lora_path_time(&m, &g, &ranks, 256.0, LoraExec::WideFused, 1.0);
        assert!(per > grouped, "per-adapter {per} vs grouped {grouped}");
        assert!(wide > grouped, "wide {wide} vs grouped {grouped}");
    }

    #[test]
    fn efficiency_saturates() {
        let g = GpuSpec::h100_sxm5();
        assert_eq!(gemm_efficiency(1e6, 4096.0, &g), 1.0);
        // LoRA-shaped GEMM (narrow output) underfills badly
        assert!(gemm_efficiency(64.0, 16.0, &g) < 0.05);
        // wider batch fills more tiles
        assert!(
            gemm_efficiency(256.0, 4096.0, &g) < gemm_efficiency(2048.0, 4096.0, &g)
        );
        let m = MODEL_FAMILY.get("llama-8b").unwrap();
        let e = base_gemm_efficiency(&m, 1024.0, &g);
        assert!(e > 0.9, "1024 tokens should nearly saturate, got {e}");
    }

    #[test]
    fn breakdown_total_is_critical_path() {
        let b = StepBreakdown {
            compute_s: 2.0,
            memory_s: 3.0,
            lora_s: 0.5,
            comm_s: 1.0,
            launch_s: 0.5,
            bubble_s: 0.25,
            idle_frac: 0.0,
        };
        assert_eq!(b.total(), 3.0 + 0.5 + 1.0 + 0.5 + 0.25);
    }
}
