//! Per-strategy step-time models: ALTO's batched grouped-GEMM executor and
//! Adapter Parallelism vs. the baselines the paper compares against
//! (Sequential, mLoRA, LoRAFusion, FSDP, TP, PP — §8.1, Fig 9/13,
//! Table 2).
//!
//! Every strategy answers one question: *how long does it take to advance
//! all N adapters by one optimizer step* on `p` GPUs.  The breakdowns are
//! roofline + α-β collective arithmetic over `GpuSpec` constants, so the
//! *ratios* between strategies (who wins, where the crossovers fall) are
//! hardware-parametric — the property the paper's figures measure.

pub mod baselines;
pub mod workload;

pub use baselines::{all_strategies, strategy_by_name};
pub use workload::{StepBreakdown, Strategy, Workload};
