//! The strategy zoo: ALTO's batched executor + Adapter Parallelism, and
//! every baseline the paper evaluates against (Sequential, mLoRA,
//! LoRAFusion, FSDP, TP, PP).  All times are "advance all N adapters by
//! one optimizer step".

use crate::cluster::comm::{allgather_time, allreduce_time, p2p_time};
use crate::cluster::gpu::GpuSpec;
use crate::config::ModelShape;

use super::workload::{
    activation_stream_time, base_compute_time, base_gemm_efficiency,
    base_weight_stream_time, gemm_efficiency, lora_path_time, LoraExec,
    StepBreakdown, Strategy, Workload,
};

/// Fixed host-side overhead per optimizer step (dataloader, launch queue,
/// optimizer bookkeeping) — identical for every strategy.
const HOST_OVERHEAD_S: f64 = 50e-6;

/// Pipeline stage-imbalance factor: mLoRA and LoRAFusion "both rely on
/// pipeline parallelism, which suffers from workload imbalance across
/// stages, even with careful scheduling" (paper §9) — the critical path
/// is set by the slowest stage, modeled at 1.3× the mean stage.
const PP_STAGE_IMBALANCE: f64 = 1.3;

/// Launch count per training step for a grouped (O(1)-launch) LoRA path:
/// per layer, 7 projections × (1 base GEMM + shrink + expand + bwd-input
/// + 2 grouped weight grads).
fn grouped_launches(model: &ModelShape) -> f64 {
    (model.n_layers * 7 * 6) as f64
}

// ---------------------------------------------------------------------------
// ALTO batched executor (single GPU) / Adapter Parallelism (multi GPU)
// ---------------------------------------------------------------------------

/// ALTO: grouped-GEMM batched multi-LoRA on one rank; rank-local Adapter
/// Parallelism when p > 1 (paper §6).
pub struct Alto;

impl Strategy for Alto {
    fn name(&self) -> &'static str {
        "alto"
    }

    fn step_time(&self, w: &Workload, gpu: &GpuSpec, p: usize) -> StepBreakdown {
        let p = p.max(1);
        // Adapters partition across ranks; the slowest rank carries
        // ⌈N/p⌉ of them (ranks step in lockstep for the all-gather).
        let per_rank = w.ranks.len().div_ceil(p);
        let rank_ranks = &w.ranks[..per_rank.min(w.ranks.len())];
        let tokens_rank = per_rank as f64 * w.tokens_per_adapter();

        let eff = base_gemm_efficiency(&w.model, tokens_rank, gpu);
        let compute = base_compute_time(&w.model, gpu, tokens_rank, 1, eff);
        // gathered weights streamed fwd + bwd
        let memory = base_weight_stream_time(&w.model, gpu, 1, 2.0)
            + activation_stream_time(&w.model, gpu, tokens_rank, 1);
        // adapters read exactly once per pass on exactly one rank
        // (§6.2 advantage iii): replication = 1
        let lora = lora_path_time(
            &w.model,
            gpu,
            rank_ranks,
            w.tokens_per_adapter(),
            LoraExec::Grouped,
            1.0,
        );
        // FSDP-style base-weight all-gather fwd + bwd; NO adapter gradient
        // communication (§6.2 advantage ii)
        let comm = if p > 1 {
            2.0 * allgather_time(gpu, w.model.base_weight_bytes(), p)
        } else {
            0.0
        };
        StepBreakdown {
            compute_s: compute,
            memory_s: memory,
            lora_s: lora,
            comm_s: comm,
            launch_s: grouped_launches(&w.model) * gpu.launch_overhead + HOST_OVERHEAD_S,
            bubble_s: 0.0,
            idle_frac: 0.0,
        }
    }
}

// ---------------------------------------------------------------------------
// Sequential (one adapter at a time, the PEFT/LLamaFactory default)
// ---------------------------------------------------------------------------

pub struct Sequential;

impl Strategy for Sequential {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn step_time(&self, w: &Workload, gpu: &GpuSpec, _p: usize) -> StepBreakdown {
        // single-GPU semantics regardless of p (the paper's Sequential
        // baseline runs on one GPU)
        let mut out = StepBreakdown::default();
        for &r in &w.ranks {
            let tok = w.tokens_per_adapter();
            let eff = base_gemm_efficiency(&w.model, tok, gpu);
            out.compute_s += base_compute_time(&w.model, gpu, tok, 1, eff);
            out.memory_s += base_weight_stream_time(&w.model, gpu, 1, 2.0)
                + activation_stream_time(&w.model, gpu, tok, 1);
            out.lora_s += lora_path_time(&w.model, gpu, &[r], tok, LoraExec::Grouped, 1.0);
            out.launch_s +=
                grouped_launches(&w.model) * gpu.launch_overhead + HOST_OVERHEAD_S;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// mLoRA (batched backbone + 3N per-layer LoRA launches; PP across GPUs)
// ---------------------------------------------------------------------------

pub struct MLora;

impl Strategy for MLora {
    fn name(&self) -> &'static str {
        "mlora"
    }

    fn step_time(&self, w: &Workload, gpu: &GpuSpec, p: usize) -> StepBreakdown {
        let p = p.max(1);
        let n = w.ranks.len() as f64;
        let tokens = w.total_tokens();
        let eff = base_gemm_efficiency(&w.model, tokens / p as f64, gpu);
        let compute = base_compute_time(&w.model, gpu, tokens, p, eff);
        let memory = base_weight_stream_time(&w.model, gpu, p, 2.0)
            + activation_stream_time(&w.model, gpu, tokens, p);
        // per-adapter LoRA kernels at vector granularity: poor occupancy
        // AND ~half effective HBM bandwidth (BGMV-style, §6.1)
        let lora = lora_path_time(
            &w.model,
            gpu,
            &w.ranks,
            w.tokens_per_adapter(),
            LoraExec::PerAdapter { bw_eff: 0.5 },
            1.0,
        );
        // 3N separate LoRA launches per layer (paper §6.1) + base GEMMs
        let launches =
            (w.model.n_layers * 7) as f64 * (1.0 + 3.0 * n) + grouped_launches(&w.model);
        // multi-GPU mLoRA = pipeline parallelism with adapter streaming:
        // bubble shrinks with in-flight microbatches (= adapters)
        let bubble = if p > 1 {
            let m = n.max(1.0);
            let work = compute.max(memory) + lora;
            let per_stage = work / p as f64;
            per_stage * (p as f64 - 1.0) / m
                + (PP_STAGE_IMBALANCE - 1.0) * work
                + (w.model.n_layers as f64)
                    * p2p_time(gpu, tokens * w.model.d_model as f64 * 2.0 / p as f64)
        } else {
            0.0
        };
        StepBreakdown {
            compute_s: compute,
            memory_s: memory,
            lora_s: lora,
            comm_s: 0.0,
            launch_s: launches * gpu.launch_overhead + HOST_OVERHEAD_S,
            bubble_s: bubble,
            idle_frac: 0.0,
        }
    }
}

// ---------------------------------------------------------------------------
// LoRAFusion (fused wide-GEMM Triton kernel; PP across GPUs)
// ---------------------------------------------------------------------------

pub struct LoraFusion;

impl Strategy for LoraFusion {
    fn name(&self) -> &'static str {
        "lorafusion"
    }

    fn step_time(&self, w: &Workload, gpu: &GpuSpec, p: usize) -> StepBreakdown {
        let p = p.max(1);
        let n = w.ranks.len() as f64;
        let tokens = w.total_tokens();
        // fusing base+LoRA into one Triton kernel sacrifices ~15% of
        // cuBLAS throughput on the base GEMM (paper §6.1, [62])
        let eff = 0.85 * base_gemm_efficiency(&w.model, tokens / p as f64, gpu);
        let compute = base_compute_time(&w.model, gpu, tokens, p, eff);
        let memory = base_weight_stream_time(&w.model, gpu, p, 2.0)
            + activation_stream_time(&w.model, gpu, tokens, p);
        // wide-GEMM: (Σ L_i)(Σ r_i) FLOPs, only Σ L_i·r_i useful
        let lora = lora_path_time(
            &w.model,
            gpu,
            &w.ranks,
            w.tokens_per_adapter(),
            LoraExec::WideFused,
            1.0,
        );
        // single fused launch per projection, fwd + bwd
        let launches = (w.model.n_layers * 7 * 3) as f64;
        let bubble = if p > 1 {
            let m = n.max(1.0);
            let work = compute.max(memory) + lora;
            let per_stage = work / p as f64;
            per_stage * (p as f64 - 1.0) / m + (PP_STAGE_IMBALANCE - 1.0) * work
        } else {
            0.0
        };
        StepBreakdown {
            compute_s: compute,
            memory_s: memory,
            lora_s: lora,
            comm_s: 0.0,
            launch_s: launches * gpu.launch_overhead + HOST_OVERHEAD_S,
            bubble_s: bubble,
            idle_frac: 0.0,
        }
    }
}

// ---------------------------------------------------------------------------
// FSDP (the de facto standard: one adapter at a time, batch split over p)
// ---------------------------------------------------------------------------

pub struct Fsdp;

impl Strategy for Fsdp {
    fn name(&self) -> &'static str {
        "fsdp"
    }

    fn step_time(&self, w: &Workload, gpu: &GpuSpec, p: usize) -> StepBreakdown {
        let p = p.max(1);
        let mut out = StepBreakdown::default();
        // global batch cannot go below world size: pad (paper footnote 3)
        let eff_batch = w.batch_per_adapter.max(p);
        let idle = 1.0 - w.batch_per_adapter.min(p) as f64 / p as f64;
        for &r in &w.ranks {
            let tok_rank = (eff_batch as f64 / p as f64) * w.seq_len as f64;
            let eff = base_gemm_efficiency(&w.model, tok_rank, gpu);
            out.compute_s += base_compute_time(&w.model, gpu, tok_rank, 1, eff);
            // every rank streams the FULL gathered weights and its own
            // replica of the adapter (paper §6.2: P× redundant traffic,
            // paid in parallel → per-rank time, replication charged 1
            // here; the waste shows up as cluster-wide traffic)
            out.memory_s += base_weight_stream_time(&w.model, gpu, 1, 2.0)
                + activation_stream_time(&w.model, gpu, tok_rank, 1);
            out.lora_s +=
                lora_path_time(&w.model, gpu, &[r], tok_rank, LoraExec::Grouped, 1.0);
            // all-gather weights fwd + bwd, all-reduce adapter grads
            out.comm_s += 2.0 * allgather_time(gpu, w.model.base_weight_bytes(), p)
                + allreduce_time(gpu, w.model.lora_weight_bytes(r) * 2.0, p);
            out.launch_s +=
                grouped_launches(&w.model) * gpu.launch_overhead + HOST_OVERHEAD_S;
        }
        out.idle_frac = idle;
        out
    }
}

// ---------------------------------------------------------------------------
// Tensor parallelism (per-layer activation all-reduce)
// ---------------------------------------------------------------------------

pub struct TensorParallel;

impl Strategy for TensorParallel {
    fn name(&self) -> &'static str {
        "tp"
    }

    fn step_time(&self, w: &Workload, gpu: &GpuSpec, p: usize) -> StepBreakdown {
        let p = p.max(1);
        let mut out = StepBreakdown::default();
        for &r in &w.ranks {
            let tok = w.tokens_per_adapter();
            // each GEMM split p ways: narrower output → worse tile fill
            let eff = gemm_efficiency(tok, w.model.d_model as f64 / p as f64, gpu);
            out.compute_s += base_compute_time(&w.model, gpu, tok, p, eff);
            out.memory_s += base_weight_stream_time(&w.model, gpu, p, 2.0)
                + activation_stream_time(&w.model, gpu, tok, p);
            // LoRA GEMMs split p ways: microscopic shards, poor bandwidth
            out.lora_s += lora_path_time(
                &w.model,
                gpu,
                &[r],
                tok,
                LoraExec::PerAdapter { bw_eff: 0.5 },
                1.0,
            ) / p as f64;
            // 2 all-reduces per layer, fwd + bwd ⇒ 4, of the activation
            // tile (tok × d, bf16); latency dwarfs the µs LoRA GEMMs
            let act_bytes = tok * w.model.d_model as f64 * 2.0;
            out.comm_s +=
                (w.model.n_layers as f64) * 4.0 * allreduce_time(gpu, act_bytes, p);
            out.launch_s +=
                grouped_launches(&w.model) * gpu.launch_overhead + HOST_OVERHEAD_S;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Pipeline parallelism (stages = p, adapters processed sequentially)
// ---------------------------------------------------------------------------

pub struct PipelineParallel;

impl Strategy for PipelineParallel {
    fn name(&self) -> &'static str {
        "pp"
    }

    fn step_time(&self, w: &Workload, gpu: &GpuSpec, p: usize) -> StepBreakdown {
        let p = p.max(1);
        let mut out = StepBreakdown::default();
        for &r in &w.ranks {
            let tok = w.tokens_per_adapter();
            // micro-batch = 1 sample; m in-flight microbatches
            let m = w.batch_per_adapter.max(1) as f64;
            let eff = base_gemm_efficiency(&w.model, tok / m, gpu);
            let work = base_compute_time(&w.model, gpu, tok, p, eff)
                + base_weight_stream_time(&w.model, gpu, p, 2.0)
                + lora_path_time(&w.model, gpu, &[r], tok, LoraExec::Grouped, 1.0);
            // bubble: (p−1)/(m+p−1) of the pipeline is idle (paper §2.2)
            let bubble = work * (p as f64 - 1.0) / m;
            // stage-boundary activation transfers
            let act_bytes = (tok / m) * w.model.d_model as f64 * 2.0;
            let transfers = m * 2.0 * (p as f64 - 1.0) * p2p_time(gpu, act_bytes);
            out.compute_s += work;
            out.bubble_s += bubble + transfers;
            out.launch_s +=
                grouped_launches(&w.model) * gpu.launch_overhead + HOST_OVERHEAD_S;
        }
        out.idle_frac = (p as f64 - 1.0) / (w.batch_per_adapter as f64 + p as f64 - 1.0);
        out
    }
}

// ---------------------------------------------------------------------------

pub fn all_strategies() -> Vec<Box<dyn Strategy>> {
    vec![
        Box::new(Alto),
        Box::new(Sequential),
        Box::new(MLora),
        Box::new(LoraFusion),
        Box::new(Fsdp),
        Box::new(TensorParallel),
        Box::new(PipelineParallel),
    ]
}

pub fn strategy_by_name(name: &str) -> Option<Box<dyn Strategy>> {
    all_strategies().into_iter().find(|s| s.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MODEL_FAMILY;

    fn wl(n: usize, b: usize, seq: usize, model: &str) -> Workload {
        Workload {
            model: MODEL_FAMILY.get(model).unwrap(),
            ranks: vec![16; n],
            batch_per_adapter: b,
            seq_len: seq,
        }
    }

    #[test]
    fn alto_beats_sequential_single_gpu() {
        // Table 2 shape: batched grouped execution wins, most at small
        // per-adapter batch (paper: 5.1× at b=1 → 2.5× at b=4, 1B model)
        let g = GpuSpec::h100_sxm5();
        let speedup = |b: usize| {
            let w = wl(32, b, 256, "llama-1b");
            Sequential.step_time(&w, &g, 1).total() / Alto.step_time(&w, &g, 1).total()
        };
        let s1 = speedup(1);
        let s2 = speedup(2);
        let s4 = speedup(4);
        assert!(s1 > s2 && s2 > s4, "monotone decay: {s1:.2} {s2:.2} {s4:.2}");
        assert!(s1 > 2.5 && s1 < 12.0, "paper-magnitude at b=1: {s1:.2}");
        assert!(s4 > 1.2, "still wins at b=4: {s4:.2}");
    }

    #[test]
    fn alto_beats_mlora_and_lorafusion() {
        let g = GpuSpec::h100_sxm5();
        for &b in &[1usize, 2, 4] {
            let w = wl(32, b, 256, "llama-1b");
            let alto = Alto.step_time(&w, &g, 1).total();
            let ml = MLora.step_time(&w, &g, 1).total();
            let lf = LoraFusion.step_time(&w, &g, 1).total();
            assert!(ml > alto, "b={b} mlora {ml} vs alto {alto}");
            assert!(lf > alto, "b={b} lorafusion {lf} vs alto {alto}");
        }
    }

    #[test]
    fn fused_vs_back_to_back_ratio_decays_with_batch() {
        // Table 2's "Fused vs PyTorch" column: 1.91× → 1.36× as b grows.
        // PyTorch back-to-back ≈ batched backbone + per-adapter LoRA,
        // which is exactly our mLoRA kernel model on one GPU.
        let g = GpuSpec::h100_sxm5();
        let ratio = |b: usize| {
            let w = wl(32, b, 256, "llama-1b");
            MLora.step_time(&w, &g, 1).total() / Alto.step_time(&w, &g, 1).total()
        };
        let (r1, r4) = (ratio(1), ratio(4));
        assert!(r1 > r4, "{r1:.2} vs {r4:.2}");
        assert!(r1 > 1.2 && r1 < 4.0, "paper magnitude ~1.9×: {r1:.2}");
    }

    #[test]
    fn ap_beats_fsdp_most_at_small_batch() {
        // Fig 13: 8 adapters, seq 256, 4×H100; AP peaks ~4.7× at bs 2
        let g = GpuSpec::h100_sxm5();
        let mut speedups = vec![];
        for &b in &[1usize, 2, 4, 8] {
            let w = wl(8, b, 256, "llama-8b");
            let ap = Alto.step_time(&w, &g, 4).total();
            let fsdp = Fsdp.step_time(&w, &g, 4).total();
            speedups.push(fsdp / ap);
        }
        // wins everywhere
        assert!(speedups.iter().all(|&s| s > 1.5), "{speedups:?}");
        // peak in the small-batch regime, decaying by bs=8
        assert!(speedups[0] > speedups[3], "{speedups:?}");
        assert!(
            speedups[0] > 3.0 && speedups[0] < 12.0,
            "peak should be paper-magnitude: {speedups:?}"
        );
    }

    #[test]
    fn ap_beats_tp_and_pp_multi_gpu() {
        let g = GpuSpec::h100_sxm5();
        let w = wl(8, 2, 256, "llama-8b");
        let ap = Alto.step_time(&w, &g, 4).total();
        assert!(TensorParallel.step_time(&w, &g, 4).total() > ap);
        assert!(PipelineParallel.step_time(&w, &g, 4).total() > ap);
    }

    #[test]
    fn fsdp_idle_fraction_below_world_size() {
        let g = GpuSpec::h100_sxm5();
        let w = wl(4, 1, 256, "llama-70b");
        let b = Fsdp.step_time(&w, &g, 4);
        assert!((b.idle_frac - 0.75).abs() < 1e-9);
        let w4 = wl(4, 4, 256, "llama-70b");
        assert_eq!(Fsdp.step_time(&w4, &g, 4).idle_frac, 0.0);
    }

    #[test]
    fn pp_bubble_shrinks_with_microbatches() {
        let g = GpuSpec::h100_sxm5();
        let w1 = wl(4, 1, 256, "llama-70b");
        let w8 = wl(4, 8, 256, "llama-70b");
        let b1 = PipelineParallel.step_time(&w1, &g, 4);
        let b8 = PipelineParallel.step_time(&w8, &g, 4);
        assert!(b1.idle_frac > b8.idle_frac);
    }

    #[test]
    fn throughput_positive_for_all() {
        let g = GpuSpec::h100_sxm5();
        let w = wl(8, 2, 256, "qwen-32b");
        for s in all_strategies() {
            let tp = s.throughput(&w, &g, 2);
            assert!(tp > 0.0, "{} tput {tp}", s.name());
        }
    }

    #[test]
    fn ap_advantage_grows_with_scale() {
        // Fig 9: multi-GPU gains (13.8×) exceed single-GPU gains (9.5×);
        // proxy: AP-vs-FSDP advantage at 70B/4GPU ≥ advantage at 32B/2GPU
        let g = GpuSpec::h100_sxm5();
        let adv = |model: &str, p: usize| {
            let w = wl(8, 2, 256, model);
            Fsdp.step_time(&w, &g, p).total() / Alto.step_time(&w, &g, p).total()
        };
        assert!(adv("llama-70b", 4) > 1.5);
        assert!(adv("qwen-32b", 2) > 1.5);
    }

    #[test]
    fn registry_lookup() {
        assert!(strategy_by_name("alto").is_some());
        assert!(strategy_by_name("fsdp").is_some());
        assert!(strategy_by_name("ddp").is_none());
    }
}
