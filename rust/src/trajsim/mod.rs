//! Parametric loss-trajectory simulator — the substrate standing in for
//! the paper's 165-config H100 sweeps (DESIGN.md §3).
//!
//! The early-exit detectors (Algorithm 1) only ever observe sequences of
//! (train, val) losses, so sweep-scale experiments (Fig 9/12/15) can run
//! on simulated trajectories whose *regimes* — converge / diverge /
//! overfit / underperform (paper Fig 6) — are parametric in the
//! hyperparameters and calibrated against the real tiny-family sweeps
//! (EXPERIMENTS.md).  Trajectories are pure functions of (config, seed,
//! step): the prefix a detector saw during warmup is bit-identical to the
//! prefix of the full run, which replay-based tests rely on — and which
//! makes trajectory evaluation *prefix-resumable* ([`SimJob::segment_losses`]):
//! the streaming harness can checkpoint a body at any segment boundary
//! as a bare step index and resume later with identical bits.
//!
//! Loss *values* here are deliberately independent of executor width and
//! placement — what a config learns does not depend on who it shares a
//! GPU with.  What co-location and placement *do* change is wall time,
//! and that is owned entirely by [`crate::perfmodel`]: `SimBackend`
//! prices each step through the `StepTimeModel`, and the simharness
//! charges placement comm cost and island contention on top, so
//! GPU-seconds accounting uses charged (not nominal) durations in both
//! `simulate_trace` and `replay`.

use crate::config::HyperParams;
use crate::data::synth::DatasetProfile;
use crate::util::rng::Pcg32;

/// Which qualitative regime a configuration lands in (paper Fig 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    Converging,
    Diverging,
    Overfitting,
    Underperforming,
}

/// A simulated training job: deterministic loss trajectories + final
/// downstream quality.
#[derive(Debug, Clone)]
pub struct SimJob {
    pub hp: HyperParams,
    pub profile: DatasetProfile,
    pub total_steps: usize,
    pub seed: u64,
    pub regime: Regime,
    // trajectory parameters (fixed at construction)
    floor: f64,
    tau: f64,
    alpha: f64,
    diverge_step: usize,
    overfit_step: usize,
    overfit_rate: f64,
    noise: f64,
    rank_penalty: f64,
}

/// Per-segment signal the rank-adaptation policy
/// ([`crate::sched::rank::RankPolicy`]) consumes.  `sensitivity` is
/// signed: positive means rank binds (growing would lower the loss
/// floor), negative means capacity is wasted (overfitting onset or a
/// plateaued high-rank config) and shrinking is safe.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RankSignal {
    /// Relative per-step val-loss slope over the segment (negative =
    /// still improving).
    pub slope: f64,
    /// `|slope|` below [`PLATEAU_SLOPE`] — the trajectory has flattened.
    pub plateau: bool,
    /// Signed rank-sensitivity: + grow, − shrink (see above).
    pub sensitivity: f64,
}

/// Relative per-step slope below which a segment counts as plateaued.
/// Late converged segments sit around 1e-4; early descending segments
/// around 1e-3 and above.
pub const PLATEAU_SLOPE: f64 = 2e-4;

/// The lr the simulator treats as optimal (paper-scale: 2e-4 sits at the
/// center of the sensible band in §A.4).
pub const LR_OPT: f64 = 2e-4;
/// Above this, divergence becomes likely (paper: "excessively large
/// learning rates never converge").
pub const LR_DIVERGE: f64 = 4e-4;

impl SimJob {
    pub fn new(
        hp: &HyperParams,
        profile: &DatasetProfile,
        total_steps: usize,
        seed: u64,
    ) -> SimJob {
        let mut rng = Pcg32::new(seed, 0x51b0 ^ hash_hp(hp));

        // --- configuration quality -> loss floor -------------------------
        // lr: log-Gaussian quality bump around LR_OPT
        let lr_dev = (hp.lr / LR_OPT).ln();
        let lr_penalty = 0.35 * lr_dev * lr_dev / 2.0;
        // batch: small batches statistically preferred (paper Fig 3);
        // penalty grows smoothly past b ≈ 8 and is mild below
        let b = hp.batch_size as f64;
        let batch_penalty = if b <= 8.0 {
            0.01 * (b / 8.0)
        } else {
            0.12 * (b / 8.0).ln() * (b / 8.0).ln() + 0.02
        };
        // rank: underfit at very low rank; mild noise otherwise
        let rank_penalty = if hp.rank < 4 { 0.15 } else { 0.01 * rng.f64() };
        let idiosyncratic = 0.08 * rng.normal().abs();
        let floor = profile.loss_floor
            * (1.0 + lr_penalty + batch_penalty + rank_penalty + idiosyncratic);

        // --- regime selection --------------------------------------------
        let p_diverge = if hp.lr >= LR_DIVERGE {
            0.85
        } else if hp.lr >= LR_OPT * 1.25 {
            0.25
        } else {
            0.02
        };
        // overfitting: aggressive lr + high rank + dataset propensity
        // under a multi-epoch schedule (paper §5.1 pattern 2)
        let p_overfit = (0.08 * profile.overfit_propensity
            * (hp.rank as f64 / 16.0).sqrt()
            * (hp.lr / LR_OPT).max(0.3).min(3.0))
        .min(0.6);
        let u = rng.f64();
        let regime = if u < p_diverge {
            Regime::Diverging
        } else if u < p_diverge + p_overfit {
            Regime::Overfitting
        } else if floor > profile.loss_floor * 1.35 {
            Regime::Underperforming
        } else {
            Regime::Converging
        };

        // convergence speed: effective step size ∝ lr (clipped), smaller
        // batches take noisier but more numerous effective steps
        let lr_eff = (hp.lr / LR_OPT).clamp(0.05, 2.5);
        let tau = (total_steps as f64 * 0.04 / lr_eff).max(2.0);

        SimJob {
            hp: hp.clone(),
            profile: *profile,
            total_steps,
            seed,
            regime,
            floor,
            tau,
            alpha: 1.2,
            diverge_step: rng.range_usize(total_steps / 20 + 1, total_steps / 2 + 2),
            // overfit onsets earlier on overfit-prone (small-data / DPO)
            // workloads — the paper's DPO runs show proportionally larger
            // overfitting savings (Fig 15)
            overfit_step: {
                let lo = ((total_steps as f64 / (4.0 * profile.overfit_propensity))
                    as usize)
                    .max(1);
                let hi = (3 * total_steps / 4).max(lo + 1);
                rng.range_usize(lo, hi)
            },
            overfit_rate: 1.2 / total_steps as f64 * (0.5 + rng.f64()),
            noise: 0.015 + 0.02 / (hp.batch_size as f64).sqrt(),
            rank_penalty,
        }
    }

    /// Noise is a pure function of (seed, step, channel) so prefixes are
    /// replay-stable.
    fn noise_at(&self, step: usize, channel: u64) -> f64 {
        let mut r = Pcg32::new(self.seed ^ (step as u64) << 17 ^ channel, 0x9e37);
        r.normal()
    }

    fn base_curve(&self, step: usize) -> f64 {
        let t = step as f64;
        self.floor
            + (self.profile.loss_init - self.floor) * (1.0 + t / self.tau).powf(-self.alpha)
    }

    /// Smoothed-ish raw training loss at `step` (0-indexed).
    pub fn train_loss(&self, step: usize) -> f64 {
        let mut l = self.base_curve(step);
        if self.regime == Regime::Diverging && step >= self.diverge_step {
            let dt = (step - self.diverge_step) as f64;
            l *= 1.0 + 0.06 * dt + 0.002 * dt * dt;
        }
        let n = self.noise_at(step, 1);
        (l * (1.0 + self.noise * n)).max(1e-4)
    }

    /// Raw validation loss at `step`.
    pub fn val_loss(&self, step: usize) -> f64 {
        let mut l = self.base_curve(step) * 1.03 + 0.01;
        if self.regime == Regime::Diverging && step >= self.diverge_step {
            let dt = (step - self.diverge_step) as f64;
            l *= 1.0 + 0.06 * dt + 0.002 * dt * dt;
        }
        if self.regime == Regime::Overfitting && step >= self.overfit_step {
            let dt = (step - self.overfit_step) as f64;
            l += self.profile.loss_floor * self.overfit_rate * dt;
        }
        let n = self.noise_at(step, 2);
        (l * (1.0 + 1.5 * self.noise * n)).max(1e-4)
    }

    /// Evaluate the (train, val) loss pair over a step range.  This
    /// codifies the prefix-resumability guarantee the streaming body
    /// path *builds on* (a `SimBackend` slot checkpoint is just a step
    /// index, because losses are pure functions of (config, seed,
    /// step)): resuming at `start` after an arbitrary pause yields
    /// bit-identical values to an uninterrupted run, with no prefix
    /// replay — pinned by `segment_resume_is_bit_identical`.
    pub fn segment_losses(&self, start: usize, end: usize) -> Vec<(f64, f64)> {
        (start..end)
            .map(|s| (self.train_loss(s), self.val_loss(s)))
            .collect()
    }

    /// Per-segment rank-adaptation signal (see [`RankSignal`]): the
    /// relative val-loss slope over `[start, end)`, a plateau flag, and
    /// a rank-sensitivity term derived from the same `rank_penalty` /
    /// overfit machinery that shaped this trajectory.  Pure function of
    /// (config, seed, segment bounds) — same bits on every evaluation,
    /// which is what lets all three engine paths plan identical resize
    /// schedules from it.
    pub fn rank_signal(&self, start: usize, end: usize) -> RankSignal {
        let end = end.min(self.total_steps).max(start + 1);
        let vals: Vec<f64> = (start..end).map(|s| self.val_loss(s)).collect();
        let half = (vals.len() / 2).max(1);
        let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
        let head = mean(&vals[..half]);
        let tail = mean(&vals[half.min(vals.len() - 1)..]);
        // relative per-step slope between the segment's two halves;
        // negative = still improving, ~0 = flat
        let slope = (tail - head) / (head.max(1e-9) * half as f64);
        let plateau = slope.abs() < PLATEAU_SLOPE;
        // grow pressure: how much the loss floor is inflated because
        // rank binds (1.0 at the hard rank<4 cliff, ≤ ~0.07 otherwise)
        let grow = self.rank_penalty / 0.15;
        // shrink pressure: overfitting past onset wants less capacity;
        // a plateaued high-rank config holds capacity it no longer uses
        let shrink = if self.regime == Regime::Overfitting && end > self.overfit_step {
            1.0
        } else if plateau {
            0.5 * (self.hp.rank as f64 / 16.0).sqrt().min(1.5)
        } else {
            0.0
        };
        RankSignal {
            slope,
            plateau,
            sensitivity: grow - shrink,
        }
    }

    /// Best (minimum) validation loss over the whole run — what a
    /// checkpoint-at-best policy recovers.
    pub fn best_val_loss(&self) -> f64 {
        (0..self.total_steps)
            .step_by((self.total_steps / 64).max(1))
            .map(|s| self.val_loss(s))
            .fold(f64::INFINITY, f64::min)
    }

    /// Downstream quality (GSM-style strict-parse accuracy in [0,1]) from
    /// the best val loss: a logistic map calibrated so that near-floor
    /// losses reach ~75% and bad configs sit at ~0% (paper Fig 1b).
    pub fn final_accuracy(&self) -> f64 {
        let l = self.best_val_loss();
        let floor = self.profile.loss_floor;
        let x = (l - 1.35 * floor) / (0.25 * floor);
        0.78 / (1.0 + x.exp())
    }

    /// DPO reward accuracy analog (paper Fig 1c: spread ~53%–80%).
    pub fn reward_accuracy(&self) -> f64 {
        let l = self.best_val_loss();
        let floor = self.profile.loss_floor;
        let x = (l - 1.35 * floor) / (0.12 * floor);
        0.50 + 0.30 / (1.0 + x.exp())
    }
}

fn hash_hp(hp: &HyperParams) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in format!("{:e}|{}|{}", hp.lr, hp.rank, hp.batch_size).bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SearchSpace;
    use crate::data::synth::dataset_profile;

    fn sweep(dataset: &str, steps: usize, seed: u64) -> Vec<SimJob> {
        let prof = dataset_profile(dataset).unwrap();
        SearchSpace::paper_single_gpu()
            .expand()
            .iter()
            .map(|hp| SimJob::new(hp, prof, steps, seed))
            .collect()
    }

    #[test]
    fn trajectories_deterministic_and_prefix_stable() {
        let prof = dataset_profile("gsm-syn").unwrap();
        let hp = HyperParams {
            lr: 2e-4,
            rank: 16,
            batch_size: 2,
        };
        let a = SimJob::new(&hp, prof, 100, 7);
        let b = SimJob::new(&hp, prof, 100, 7);
        for s in 0..100 {
            assert_eq!(a.train_loss(s), b.train_loss(s));
            assert_eq!(a.val_loss(s), b.val_loss(s));
        }
    }

    #[test]
    fn good_config_converges_toward_floor() {
        let prof = dataset_profile("gsm-syn").unwrap();
        let hp = HyperParams {
            lr: 2e-4,
            rank: 16,
            batch_size: 2,
        };
        // find a converging seed (regime selection is stochastic)
        let job = (0..20)
            .map(|s| SimJob::new(&hp, prof, 400, s))
            .find(|j| j.regime == Regime::Converging)
            .expect("a good config should usually converge");
        let early = job.train_loss(5);
        let late = job.train_loss(390);
        assert!(late < early * 0.5, "late {late} vs early {early}");
        assert!(late < prof.loss_floor * 2.0);
    }

    #[test]
    fn huge_lr_usually_diverges() {
        let prof = dataset_profile("gsm-syn").unwrap();
        let hp = HyperParams {
            lr: 5e-4,
            rank: 16,
            batch_size: 2,
        };
        let div = (0..50)
            .filter(|&s| SimJob::new(&hp, prof, 200, s).regime == Regime::Diverging)
            .count();
        assert!(div > 30, "only {div}/50 diverged at lr=5e-4");
    }

    #[test]
    fn diverging_loss_rises() {
        let prof = dataset_profile("gsm-syn").unwrap();
        let hp = HyperParams {
            lr: 5e-4,
            rank: 16,
            batch_size: 2,
        };
        let job = (0..50)
            .map(|s| SimJob::new(&hp, prof, 200, s))
            .find(|j| j.regime == Regime::Diverging)
            .unwrap();
        let at_d = job.train_loss(job.diverge_step);
        let later = job.train_loss((job.diverge_step + 50).min(199));
        assert!(later > at_d * 1.5, "{later} vs {at_d}");
    }

    #[test]
    fn overfitting_val_rises_while_train_falls() {
        let prof = dataset_profile("pref-syn").unwrap();
        let hp = HyperParams {
            lr: 3e-4,
            rank: 128,
            batch_size: 2,
        };
        let job = (0..200)
            .map(|s| SimJob::new(&hp, prof, 400, s))
            .find(|j| j.regime == Regime::Overfitting)
            .expect("high-rank aggressive config should sometimes overfit");
        let v_of = job.val_loss(job.overfit_step);
        let v_late = job.val_loss(399);
        assert!(v_late > v_of, "val should rise: {v_late} vs {v_of}");
        let t_of = job.train_loss(job.overfit_step);
        let t_late = job.train_loss(399);
        assert!(t_late <= t_of * 1.05, "train keeps falling");
    }

    #[test]
    fn sweep_shows_paper_fig1_spread() {
        // Fig 1: best-to-worst val loss spread exceeding an order of
        // magnitude; many near-zero accuracies, best ≈ 70+%
        let jobs = sweep("gsm-syn", 400, 42);
        let vals: Vec<f64> = jobs.iter().map(|j| j.best_val_loss()).collect();
        let accs: Vec<f64> = jobs.iter().map(|j| j.final_accuracy()).collect();
        let vmin = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        let vmax = vals.iter().cloned().fold(0.0, f64::max);
        assert!(vmax / vmin > 5.0, "spread {vmin}..{vmax}");
        let best = accs.iter().cloned().fold(0.0, f64::max);
        let zeros = accs.iter().filter(|&&a| a < 0.05).count();
        assert!(best > 0.5, "best acc {best}");
        assert!(zeros > 5, "near-zero configs {zeros}");
    }

    #[test]
    fn small_batches_preferred_on_average() {
        // Fig 3 shape: mean best-val-loss should rise for batch ≥ 32
        let prof = dataset_profile("gsm-syn").unwrap();
        let mean_loss = |bs: usize| {
            let mut tot = 0.0;
            let mut n = 0;
            for (i, lr) in [5e-5, 2e-4, 3e-4].iter().enumerate() {
                for seed in 0..8u64 {
                    let hp = HyperParams {
                        lr: *lr,
                        rank: 16,
                        batch_size: bs,
                    };
                    let j = SimJob::new(&hp, prof, 300, seed * 31 + i as u64);
                    tot += j.best_val_loss();
                    n += 1;
                }
            }
            tot / n as f64
        };
        let small = mean_loss(4);
        let large = mean_loss(64);
        assert!(large > small * 1.05, "large {large} vs small {small}");
    }

    #[test]
    fn warmup_losses_correlate_with_final() {
        // Fig 7: rank correlation of val loss at 5% vs end of training
        use crate::stats::spearman;
        let jobs = sweep("gsm-syn", 400, 3);
        // restrict to non-diverging (paper: "well-behaved configurations")
        let well: Vec<&SimJob> = jobs
            .iter()
            .filter(|j| j.regime != Regime::Diverging)
            .collect();
        let early: Vec<f64> = well.iter().map(|j| j.val_loss(20)).collect();
        let fin: Vec<f64> = well.iter().map(|j| j.best_val_loss()).collect();
        let rho = spearman(&early, &fin);
        assert!(rho > 0.5, "warmup correlation too weak: {rho}");
    }

    /// Interleave partial reads on `probe` the way a warmup detector
    /// would (train every step, val every 10th), peek at post-warmup
    /// steps, then check every prefix value against a fresh full replay
    /// on `replay` — bit for bit.
    fn assert_warmup_prefix_bit_identical(probe: &SimJob, replay: &SimJob, warmup: usize) {
        let total = probe.total_steps;
        let mut train = Vec::new();
        let mut val = Vec::new();
        for s in 0..warmup {
            train.push(probe.train_loss(s));
            if s % 10 == 9 {
                val.push((s, probe.val_loss(s)));
            }
        }
        // continue-training reads beyond the boundary must not perturb
        // the prefix (pure functions of (seed, step))
        let _ = probe.train_loss(total - 1);
        let _ = probe.val_loss(total - 1);
        for (s, &t) in train.iter().enumerate() {
            assert_eq!(
                t.to_bits(),
                replay.train_loss(s).to_bits(),
                "train prefix diverged at step {s}"
            );
        }
        for &(s, v) in &val {
            assert_eq!(
                v.to_bits(),
                replay.val_loss(s).to_bits(),
                "val prefix diverged at step {s}"
            );
        }
    }

    #[test]
    fn warmup_prefix_bit_identical_for_all_regimes() {
        // hunt one representative job per regime; each candidate pool is
        // chosen so its target regime is likely (see `SimJob::new`)
        let candidates: [(&str, f64, usize, Regime); 4] = [
            ("gsm-syn", 2e-4, 16, Regime::Converging),
            ("gsm-syn", 5e-4, 16, Regime::Diverging),
            ("pref-syn", 3e-4, 128, Regime::Overfitting),
            ("gsm-syn", 1e-5, 16, Regime::Underperforming),
        ];
        let total = 200;
        for (ds, lr, rank, want) in candidates {
            let prof = dataset_profile(ds).unwrap();
            let hp = HyperParams {
                lr,
                rank,
                batch_size: 2,
            };
            let job = (0..400u64)
                .map(|seed| SimJob::new(&hp, prof, total, seed))
                .find(|j| j.regime == want)
                .unwrap_or_else(|| panic!("no {want:?} job in 400 seeds"));
            let replay = SimJob::new(&hp, prof, total, job.seed);
            assert_eq!(replay.regime, want, "regime itself must replay");
            let warmup = (total / 20).max(1); // the paper's 5% boundary
            assert_warmup_prefix_bit_identical(&job, &replay, warmup);
        }
    }

    #[test]
    fn segment_resume_is_bit_identical() {
        // the streaming harness pauses a body at arbitrary segment
        // boundaries and resumes later: every resumption point must
        // yield the same bits as an uninterrupted evaluation
        let prof = dataset_profile("gsm-syn").unwrap();
        let hp = HyperParams {
            lr: 2e-4,
            rank: 16,
            batch_size: 2,
        };
        let job = SimJob::new(&hp, prof, 120, 9);
        let full = job.segment_losses(0, 120);
        for &cut in &[1usize, 7, 30, 119] {
            let fresh = SimJob::new(&hp, prof, 120, 9);
            let head = fresh.segment_losses(0, cut);
            // interleave unrelated reads between the pause and resume
            let _ = fresh.best_val_loss();
            let tail = fresh.segment_losses(cut, 120);
            for (i, &(t, v)) in head.iter().chain(tail.iter()).enumerate() {
                assert_eq!(t.to_bits(), full[i].0.to_bits(), "train step {i} cut {cut}");
                assert_eq!(v.to_bits(), full[i].1.to_bits(), "val step {i} cut {cut}");
            }
        }
    }

    #[test]
    fn rank_signal_is_deterministic_and_bounded() {
        let prof = dataset_profile("gsm-syn").unwrap();
        let hp = HyperParams {
            lr: 2e-4,
            rank: 16,
            batch_size: 2,
        };
        let a = SimJob::new(&hp, prof, 400, 13);
        let b = SimJob::new(&hp, prof, 400, 13);
        for seg in 0..4 {
            let s = seg * 100;
            let x = a.rank_signal(s, s + 100);
            let y = b.rank_signal(s, s + 100);
            // the signal is part of the resize-plan determinism story:
            // bitwise, not approximately, equal
            assert_eq!(x.slope.to_bits(), y.slope.to_bits());
            assert_eq!(x.sensitivity.to_bits(), y.sensitivity.to_bits());
            assert_eq!(x.plateau, y.plateau);
            assert!(x.slope.is_finite() && x.sensitivity.is_finite());
        }
        // out-of-range bounds clamp instead of panicking
        assert!(a.rank_signal(390, 10_000).slope.is_finite());
        assert!(a.rank_signal(399, 399).slope.is_finite());
    }

    #[test]
    fn rank_signal_direction_matches_the_regime_machinery() {
        // (1) rank-starved (rank < 4 cliff): grow pressure dominates
        let prof = dataset_profile("gsm-syn").unwrap();
        let hp = HyperParams {
            lr: 2e-4,
            rank: 2,
            batch_size: 2,
        };
        let starved = (0..100)
            .map(|s| SimJob::new(&hp, prof, 400, s))
            .find(|j| j.regime != Regime::Overfitting && j.regime != Regime::Diverging)
            .expect("a sane-lr rank-2 config should usually not overfit/diverge");
        let sig = starved.rank_signal(0, 100);
        assert!(
            sig.sensitivity > 0.75,
            "starved rank must demand growth: {sig:?}"
        );
        // and the early descent is visible in the slope
        assert!(sig.slope < 0.0, "{sig:?}");

        // (2) a converged high-rank config plateaus late: shrink is safe
        let hp = HyperParams {
            lr: 2e-4,
            rank: 64,
            batch_size: 4,
        };
        let sig = (0..200)
            .map(|s| SimJob::new(&hp, prof, 400, s))
            .find_map(|j| {
                if j.regime != Regime::Converging {
                    return None;
                }
                let sig = j.rank_signal(300, 400);
                sig.plateau.then_some(sig)
            })
            .expect("some converged high-rank job should plateau late");
        assert!(
            sig.sensitivity < -0.1,
            "plateaued high rank must shed capacity: {sig:?}"
        );

        // (3) overfitting past onset: shrink hard, whatever the slope
        let prof = dataset_profile("pref-syn").unwrap();
        let hp = HyperParams {
            lr: 3e-4,
            rank: 128,
            batch_size: 2,
        };
        let over = (0..200)
            .map(|s| SimJob::new(&hp, prof, 400, s))
            .find(|j| j.regime == Regime::Overfitting)
            .expect("high-rank aggressive config should sometimes overfit");
        let sig = over.rank_signal(over.overfit_step, over.overfit_step + 50);
        assert!(
            sig.sensitivity < -0.5,
            "overfitting past onset must shed capacity: {sig:?}"
        );
    }

    #[test]
    fn dpo_reward_accuracy_in_paper_band() {
        let jobs = sweep("pref-syn", 300, 11);
        let accs: Vec<f64> = jobs.iter().map(|j| j.reward_accuracy()).collect();
        let best = accs.iter().cloned().fold(0.0, f64::max);
        let worst = accs.iter().cloned().fold(1.0, f64::min);
        assert!(best > 0.70 && best <= 0.80, "best {best}");
        assert!(worst >= 0.45 && worst < 0.60, "worst {worst}");
    }
}
