//! # perfmodel — the single owner of step-time estimation
//!
//! Before this layer existed, duration math was scattered across four
//! consumers: `coordinator::Profiler` priced tasks with a private call
//! into `parallel::baselines::Alto`, `simharness::engine` froze those
//! prices into fixed up-front durations, the placement layer *reported*
//! a comm-cost score without ever charging it to the clock, and nothing
//! modeled what co-scheduled tenants do to each other's collectives.
//! `perfmodel` composes the existing substrates behind one API:
//!
//! * `parallel::workload` + `parallel::baselines::Alto` — the
//!   compute / weight-stream / grouped-GEMM LoRA roofline terms;
//! * `cluster::comm` + `cluster::topology` — placement-dependent
//!   collective cost at island-derated bandwidth;
//! * `cluster::memory` — executor width (via the fitted memory model the
//!   admission path consults).
//!
//! ## The model
//!
//! [`StepTimeModel`] prices one optimizer step of a [`Workload`] on a
//! concrete GPU group:
//!
//! ```text
//! t(w, p, placement, ctx) = Alto.step_time(w, derate(gpu, placement), p)
//!                           with comm_s × fabric_slowdown(ctx)
//! ```
//!
//! * **Placement derating** — a placement that spans NVLink islands
//!   drags every collective down to the inter-island fabric
//!   ([`Topology::effective_link_bw`]); single-island placements (and
//!   `None`, the "not placed yet" estimate) run at full NVLink.
//! * **Contention** — a [`ContentionCtx`] names the *foreign* adapters
//!   currently resident on the islands this placement touches; they
//!   share the NVSwitch fabric, so the collective term is inflated by
//!   [`contention::fabric_slowdown`].  Compute and HBM terms are private
//!   to each GPU and are *not* derated — only the shared fabric is.
//!
//! Two exact invariants the property suite pins:
//!
//! 1. With `placement` single-island (or `None`) and an empty
//!    [`ContentionCtx`], the model reproduces the legacy
//!    `Profiler::estimate_duration` arithmetic **bit for bit** — the
//!    refactor moves ownership, not numbers.
//! 2. Step time is monotone non-decreasing in the co-located adapter
//!    count and in cross-island span.
//!
//! ## Consumers
//!
//! * [`crate::coordinator::Profiler`] — a caching facade: memoizes
//!   `(model, n, rank, batch, seq, gpus, islands, neighbors)` →
//!   samples/s.
//! * [`crate::sched::intra`] — admission/backfill price candidate
//!   executor groups through [`crate::sched::intra::GroupPricer`]
//!   instead of slot counts alone.
//! * [`crate::sched::inter`] — start/preempt/resume decisions charge a
//!   placement- and contention-dependent factor to every running task's
//!   clock, and migrations pay a checkpoint-transfer cost
//!   (`cluster::comm::p2p_time` over the adapter + optimizer states).
//! * [`crate::simharness::engine`] — incremental re-pricing: when a
//!   cohort member exits early, is evicted, or migrates, the survivors'
//!   remaining durations are re-derived and the event clock shifts —
//!   every shift is a `Reprice` event folded into the replay digest.
//!   On the streaming path the same factors price bodies resolved
//!   lazily at start events; batch and streaming timelines stay
//!   bit-identical because the factor arithmetic is evaluated at the
//!   same clock instants in both.
//!
//! ## Reference modes and re-arming
//!
//! Pricing is charged through [`crate::sched::inter::Pricing`];
//! `Pricing::none()` restores the legacy placement-blind clock bit for
//! bit (the ablation baseline the placement-isolation tests replay).
//! Because the digest hashes raw f64 bits, *any* intentional change to
//! the model's constants invalidates the golden replay pins and the
//! committed bench baseline — both are armed by CI (the authoring
//! container has no Rust toolchain); the re-arming procedure lives in
//! `docs/ARCHITECTURE.md` and `rust/tests/golden/README.md`.

pub mod contention;
pub mod price;

pub use contention::{fabric_slowdown, ContentionCtx};
pub use price::task_workload;

use crate::cluster::gpu::GpuSpec;
use crate::cluster::{Placement, Topology};
use crate::parallel::baselines::Alto;
use crate::parallel::workload::{StepBreakdown, Strategy, Workload};

/// The unified step-time model: a device spec plus the island map the
/// cluster's placements live on.
///
/// The nominal spec is shared behind an `Arc`: the harness constructs a
/// model (and a `Profiler` facade over one) per task body on the
/// streaming path, and `GpuSpec` carries a heap `String` — one shared
/// allocation replaces a clone per construction.  Constructors accept
/// either an owned `GpuSpec` or an existing `Arc<GpuSpec>` via
/// `impl Into<Arc<GpuSpec>>`, so every pre-existing call site compiles
/// unchanged.
#[derive(Debug, Clone)]
pub struct StepTimeModel {
    gpu: std::sync::Arc<GpuSpec>,
    topo: Topology,
    /// The device as a cross-island collective sees it (`link_bw`
    /// divided by the topology's inter-island penalty), built once at
    /// construction so the pricing hot path never clones a `GpuSpec`
    /// (whose `name` is a heap `String`) per query.
    derated: GpuSpec,
}

impl StepTimeModel {
    pub fn new(gpu: impl Into<std::sync::Arc<GpuSpec>>, topo: Topology) -> StepTimeModel {
        let gpu = gpu.into();
        let mut derated = (*gpu).clone();
        derated.link_bw = gpu.link_bw / topo.inter_island_penalty;
        StepTimeModel { gpu, topo, derated }
    }

    /// A model with no island structure (one flat NVLink domain): every
    /// placement is single-island, so pricing reduces to the legacy
    /// nominal path.  This is what placement-agnostic callers (the
    /// Profiler's default, `SimBackend`) use.
    pub fn nominal(gpu: impl Into<std::sync::Arc<GpuSpec>>) -> StepTimeModel {
        StepTimeModel::new(gpu, Topology::flat(0))
    }

    pub fn gpu(&self) -> &GpuSpec {
        self.gpu.as_ref()
    }

    /// The shared nominal spec handle — lets consumers (cluster,
    /// profiler, executors) alias the same allocation instead of cloning
    /// the spec per construction.
    pub fn gpu_shared(&self) -> std::sync::Arc<GpuSpec> {
        std::sync::Arc::clone(&self.gpu)
    }

    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// Device spec as seen by a collective over `placement`: the link
    /// bandwidth drops to the inter-island fabric when the placement
    /// crosses islands; everything else is per-GPU and unchanged.
    /// Placements outside the topology's index range (e.g. against a
    /// [`StepTimeModel::nominal`] model) price at full bandwidth.
    /// Returns a borrow of one of the two precomputed specs — zero
    /// allocations per query.
    fn effective_gpu(&self, placement: Option<&Placement>) -> &GpuSpec {
        match placement {
            Some(p) if self.topo.contains(p) && self.topo.is_cross_island(p) => &self.derated,
            _ => self.gpu.as_ref(),
        }
    }

    /// Full step-time breakdown of `w` on `p_gpus` ranks, with the
    /// collective term priced at the placement's effective bandwidth and
    /// inflated by island co-location contention.
    pub fn step_time(
        &self,
        w: &Workload,
        p_gpus: usize,
        placement: Option<&Placement>,
        ctx: &ContentionCtx,
    ) -> StepBreakdown {
        let gpu = self.effective_gpu(placement);
        let mut b = Alto.step_time(w, gpu, p_gpus);
        let slow = fabric_slowdown(ctx);
        if slow != 1.0 {
            b.comm_s *= slow;
        }
        b
    }

    /// Critical-path seconds of one step (see [`StepBreakdown::total`]).
    pub fn step_total(
        &self,
        w: &Workload,
        p_gpus: usize,
        placement: Option<&Placement>,
        ctx: &ContentionCtx,
    ) -> f64 {
        self.step_time(w, p_gpus, placement, ctx).total()
    }

    /// Sustained samples/second of the workload under this pricing.
    pub fn throughput(
        &self,
        w: &Workload,
        p_gpus: usize,
        placement: Option<&Placement>,
        ctx: &ContentionCtx,
    ) -> f64 {
        let t = self.step_total(w, p_gpus, placement, ctx);
        (w.n_adapters() * w.batch_per_adapter) as f64 / t
    }

    /// Slowdown of a (placement, contention) pair relative to nominal
    /// single-island uncontended execution of the same workload.
    /// Exactly 1.0 when the placement stays inside one island and no
    /// neighbors share it — the schedulers multiply nominal durations by
    /// this, so unpriced replays stay bit-identical to the legacy path.
    pub fn charge_factor(
        &self,
        w: &Workload,
        p_gpus: usize,
        placement: Option<&Placement>,
        ctx: &ContentionCtx,
    ) -> f64 {
        self.charge_factor_given_nominal(w, p_gpus, placement, ctx, self.nominal_step_total(w, p_gpus))
    }

    /// Nominal (single-island, uncontended) critical-path seconds of one
    /// step — the denominator of [`StepTimeModel::charge_factor`].  The
    /// scheduler computes this once per task and reuses it across every
    /// re-pricing of that task (the value never changes mid-run).
    pub fn nominal_step_total(&self, w: &Workload, p_gpus: usize) -> f64 {
        Alto.step_time(w, self.gpu.as_ref(), p_gpus).total()
    }

    /// [`StepTimeModel::charge_factor`] with the nominal denominator
    /// supplied by the caller (who cached `nominal_step_total`).
    pub fn charge_factor_given_nominal(
        &self,
        w: &Workload,
        p_gpus: usize,
        placement: Option<&Placement>,
        ctx: &ContentionCtx,
        nominal: f64,
    ) -> f64 {
        if nominal <= 0.0 {
            return 1.0;
        }
        self.step_total(w, p_gpus, placement, ctx) / nominal
    }

    /// Per-member slowdown of running `own` inside a shared-executor
    /// roster whose combined workload is `combined` (same backbone,
    /// ranks = concatenation of every member's adapters — per-slot rank
    /// heterogeneity included): the grouped step over the full roster
    /// divided by the member's solo step.  This is how
    /// [`crate::sched::inter`] prices co-located tasks — intra-group
    /// rank-local parallelism, not foreign-tenant contention.
    ///
    /// Exact invariants (pinned by the property suite):
    /// * a roster spanning one task (`combined == own`) prices at
    ///   exactly 1.0 — `x / x` bitwise, so single-task groups replay the
    ///   unshared clock bit for bit;
    /// * monotone non-decreasing in roster size — appending adapters
    ///   never shrinks the grouped step;
    /// * never below 1.0 (clamped: a roster cannot speed a member up).
    pub fn group_stretch(&self, own: &Workload, combined: &Workload, p_gpus: usize) -> f64 {
        let solo = self.nominal_step_total(own, p_gpus);
        if solo <= 0.0 {
            return 1.0;
        }
        (self.nominal_step_total(combined, p_gpus) / solo).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MODEL_FAMILY;

    fn w(n: usize, model: &str) -> Workload {
        Workload {
            model: MODEL_FAMILY.get(model).unwrap(),
            ranks: vec![16; n],
            batch_per_adapter: 2,
            seq_len: 256,
        }
    }

    #[test]
    fn nominal_matches_legacy_alto_bitwise() {
        let gpu = GpuSpec::h100_sxm5();
        let m = StepTimeModel::nominal(gpu.clone());
        for p in [1usize, 2, 4] {
            let wl = w(4, "llama-8b");
            let legacy = Alto.step_time(&wl, &gpu, p).total();
            let ours = m.step_total(&wl, p, None, &ContentionCtx::default());
            assert_eq!(ours.to_bits(), legacy.to_bits(), "p={p}");
        }
    }

    #[test]
    fn single_island_placement_is_free() {
        let gpu = GpuSpec::h100_sxm5();
        let m = StepTimeModel::new(gpu.clone(), Topology::h100_nodes(16));
        let wl = w(4, "qwen-32b");
        let inside = Placement::new(vec![0, 1, 2, 3]);
        let f = m.charge_factor(&wl, 4, Some(&inside), &ContentionCtx::default());
        assert_eq!(f.to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn cross_island_costs_strictly_more() {
        let gpu = GpuSpec::h100_sxm5();
        let m = StepTimeModel::new(gpu, Topology::h100_nodes(16));
        let wl = w(4, "qwen-32b");
        let inside = Placement::new(vec![0, 1, 2, 3]);
        let across = Placement::new(vec![6, 7, 8, 9]);
        let ctx = ContentionCtx::default();
        let t_in = m.step_total(&wl, 4, Some(&inside), &ctx);
        let t_x = m.step_total(&wl, 4, Some(&across), &ctx);
        assert!(t_x > t_in, "cross-island {t_x} vs inside {t_in}");
        assert!(m.charge_factor(&wl, 4, Some(&across), &ctx) > 1.0);
    }

    #[test]
    fn contention_inflates_only_collectives() {
        let gpu = GpuSpec::h100_sxm5();
        let m = StepTimeModel::new(gpu, Topology::h100_nodes(16));
        let wl = w(4, "qwen-32b");
        let busy = ContentionCtx {
            neighbor_adapters: 8,
            neighbor_gpus: 4,
        };
        let quiet = m.step_time(&wl, 4, None, &ContentionCtx::default());
        let loud = m.step_time(&wl, 4, None, &busy);
        assert!(loud.comm_s > quiet.comm_s);
        assert_eq!(loud.compute_s.to_bits(), quiet.compute_s.to_bits());
        assert_eq!(loud.memory_s.to_bits(), quiet.memory_s.to_bits());
        assert_eq!(loud.lora_s.to_bits(), quiet.lora_s.to_bits());
        // single-GPU workloads have no collective to contend on
        let solo = m.charge_factor(&w(4, "llama-8b"), 1, None, &busy);
        assert_eq!(solo.to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn group_stretch_of_a_single_task_roster_is_exactly_one() {
        let m = StepTimeModel::nominal(GpuSpec::h100_sxm5());
        for p in [1usize, 2, 4] {
            let own = w(2, "llama-8b");
            assert_eq!(m.group_stretch(&own, &own, p).to_bits(), 1.0f64.to_bits());
        }
    }

    #[test]
    fn group_stretch_is_monotone_in_roster_size_and_at_least_one() {
        let m = StepTimeModel::nominal(GpuSpec::h100_sxm5());
        let own = w(2, "llama-8b");
        let mut last = 1.0;
        for extra in 0..6 {
            let mut combined = own.clone();
            combined.ranks.extend(std::iter::repeat(32).take(extra));
            let s = m.group_stretch(&own, &combined, 1);
            assert!(s >= 1.0, "stretch below one: {s}");
            assert!(s >= last, "stretch shrank when the roster grew: {last} -> {s}");
            last = s;
        }
        assert!(last > 1.0, "a 6-adapter roster must cost something: {last}");
    }
}
