//! Task-level pricing: worst-case duration estimates and one-off charges
//! (checkpoint transfers) derived from the step-time model.

use crate::cluster::comm;
use crate::cluster::Placement;
use crate::config::{ModelShape, TaskSpec};
use crate::parallel::workload::Workload;

use super::{ContentionCtx, StepTimeModel};

/// The representative executor workload for a task: its dominant
/// configuration — smallest batch (worst throughput per adapter, the
/// conservative planning shape), largest rank, `n_slots` co-located
/// adapters.  Exactly the shape the legacy `Profiler` measured, so the
/// duration estimates below reproduce its numbers bit for bit when
/// placement and contention are trivial.
pub fn task_workload(model: &ModelShape, task: &TaskSpec, n_slots: usize) -> Workload {
    let batch = *task.search_space.batch_sizes.iter().min().unwrap_or(&1);
    let rank = task.search_space.ranks.iter().copied().max().unwrap_or(16);
    Workload {
        model: model.clone(),
        ranks: vec![rank; n_slots.max(1)],
        batch_per_adapter: batch,
        seq_len: task.seq_len,
    }
}

impl StepTimeModel {
    /// Worst-case duration estimate d_i for a task: total samples over
    /// the sustained throughput of its dominant configuration, priced at
    /// the given placement and co-location context.  With `placement`
    /// `None`/single-island and an empty context this is the legacy
    /// `Profiler::estimate_duration` arithmetic, bit for bit.
    pub fn estimate_task_duration(
        &self,
        model: &ModelShape,
        task: &TaskSpec,
        n_slots: usize,
        placement: Option<&Placement>,
        ctx: &ContentionCtx,
    ) -> f64 {
        let w = task_workload(model, task, n_slots);
        let tput = self.throughput(&w, task.num_gpus, placement, ctx);
        task.total_samples() as f64 / tput
    }

    /// Checkpoint-transfer cost of migrating a task between placements:
    /// the adapter weights plus AdamW moments (fp32, ×3 states) of
    /// `n_slots` resident adapters of rank `rank`, moved point-to-point —
    /// at the inter-island fabric rate when the move leaves the island.
    pub fn migration_cost(
        &self,
        model: &ModelShape,
        rank: usize,
        n_slots: usize,
        from: &Placement,
        to: &Placement,
    ) -> f64 {
        let bytes =
            3.0 * 4.0 * model.lora_param_count(rank) as f64 * n_slots.max(1) as f64;
        let mut gpu = self.gpu().clone();
        let topo = self.topo();
        if topo.contains(from) && topo.contains(to) {
            let union =
                Placement::new(from.gpus().iter().chain(to.gpus()).copied().collect());
            if topo.is_cross_island(&union) {
                gpu.link_bw = self.gpu().link_bw / topo.inter_island_penalty;
            }
        }
        comm::p2p_time(&gpu, bytes)
    }

    /// Checkpoint-transfer cost of re-allocating a task's LoRA rank in
    /// place (dynamic rank reallocation): the resident adapter state at
    /// the *larger* of the two ranks — a grow re-materializes the new
    /// adapters from checkpoint, a shrink spills the old ones — moved
    /// point-to-point over the placement it keeps.  Delegates to
    /// [`Self::migration_cost`] with `from == to`, so a placement that
    /// already spans islands pays the same fabric penalty a migration
    /// would.
    pub fn resize_cost(
        &self,
        model: &ModelShape,
        old_rank: usize,
        new_rank: usize,
        n_slots: usize,
        placement: &Placement,
    ) -> f64 {
        self.migration_cost(
            model,
            old_rank.max(new_rank),
            n_slots,
            placement,
            placement,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::GpuSpec;
    use crate::cluster::Topology;
    use crate::config::{SearchSpace, MODEL_FAMILY};

    fn task(model: &str, gpus: usize) -> TaskSpec {
        TaskSpec {
            model: model.into(),
            num_gpus: gpus,
            search_space: SearchSpace::paper_single_gpu(),
            seq_len: 512,
            train_samples: 1000,
            ..TaskSpec::default()
        }
    }

    #[test]
    fn dominant_workload_shape() {
        let m = MODEL_FAMILY.get("llama-8b").unwrap();
        let w = task_workload(&m, &task("llama-8b", 1), 4);
        assert_eq!(w.n_adapters(), 4);
        assert_eq!(w.batch_per_adapter, 1); // smallest batch in the space
        assert_eq!(w.ranks, vec![64; 4]); // largest rank in the space
        assert_eq!(w.seq_len, 512);
        // zero-slot callers still get a one-adapter estimate
        assert_eq!(task_workload(&m, &task("llama-8b", 1), 0).n_adapters(), 1);
    }

    #[test]
    fn duration_scales_with_samples_and_model_size() {
        let model = StepTimeModel::nominal(GpuSpec::h100_sxm5());
        let small = MODEL_FAMILY.get("llama-8b").unwrap();
        let big = MODEL_FAMILY.get("llama-70b").unwrap();
        let ctx = ContentionCtx::empty();
        let mut t = task("llama-8b", 1);
        let d1 = model.estimate_task_duration(&small, &t, 4, None, &ctx);
        t.train_samples = 2000;
        let d2 = model.estimate_task_duration(&small, &t, 4, None, &ctx);
        assert!((d2 / d1 - 2.0).abs() < 0.01, "{d1} vs {d2}");
        let db = model.estimate_task_duration(&big, &task("llama-70b", 1), 4, None, &ctx);
        assert!(db > d1 * 3.0, "{db} vs {d1}");
    }

    #[test]
    fn migration_cost_positive_and_island_sensitive() {
        let model = StepTimeModel::new(GpuSpec::h100_sxm5(), Topology::h100_nodes(16));
        let shape = MODEL_FAMILY.get("llama-8b").unwrap();
        let a = Placement::new(vec![0, 1]);
        let b = Placement::new(vec![2, 3]);
        let far = Placement::new(vec![8, 9]);
        let near = model.migration_cost(&shape, 16, 4, &a, &b);
        let cross = model.migration_cost(&shape, 16, 4, &a, &far);
        assert!(near > 0.0);
        assert!(cross > near, "cross-island move must cost more: {cross} vs {near}");
        // more resident state costs more to move
        assert!(model.migration_cost(&shape, 16, 8, &a, &b) > near);
        assert!(model.migration_cost(&shape, 64, 4, &a, &b) > near);
    }

    #[test]
    fn resize_cost_charges_the_larger_rank() {
        let model = StepTimeModel::new(GpuSpec::h100_sxm5(), Topology::h100_nodes(16));
        let shape = MODEL_FAMILY.get("llama-8b").unwrap();
        let p = Placement::new(vec![0, 1]);
        let grow = model.resize_cost(&shape, 16, 32, 4, &p);
        let shrink = model.resize_cost(&shape, 32, 16, 4, &p);
        assert!(grow > 0.0);
        // symmetric: both directions price the max(old, new) state
        assert_eq!(grow.to_bits(), shrink.to_bits());
        // and exactly the in-place migration of that state
        let same = model.migration_cost(&shape, 32, 4, &p, &p);
        assert_eq!(grow.to_bits(), same.to_bits());
        // a bigger rank band costs more
        assert!(model.resize_cost(&shape, 16, 64, 4, &p) > grow);
        // an island-spanning placement pays the fabric penalty
        let spanning = Placement::new(vec![7, 8]);
        assert!(
            model.resize_cost(&shape, 16, 32, 4, &spanning) > grow,
            "cross-island resident state must cost more to respill"
        );
    }
}
