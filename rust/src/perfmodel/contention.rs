//! Co-location contention: what sharing an NVLink island costs.
//!
//! Placements in this cluster are pairwise disjoint at the GPU level, so
//! tenants never fight over SMs or HBM — those are private to each GPU.
//! What they *do* share is the island's NVSwitch fabric: every
//! co-resident tenant's all-gathers ride the same switch ports, so a
//! task's collectives slow down as more foreign adapters train on its
//! islands (the PLoRA/tLoRA co-location observation).  The model is a
//! deliberately simple linear pressure term — each foreign adapter slot
//! claims a small fixed fraction of the fabric — capped so a crowded
//! island degrades gracefully instead of diverging.
//!
//! Single-GPU tasks have no collective term, so contention (correctly)
//! never slows them; the slowdown is monotone non-decreasing in the
//! neighbor count, which `rust/tests/perfmodel_props.rs` pins.

/// The foreign adapters currently sharing resources with a priced
/// workload's GPU group: everything resident on the NVLink islands its
/// placement touches, excluding the workload's own adapters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ContentionCtx {
    /// Executor slots (co-located adapters) other tenants keep resident
    /// on the shared islands.
    pub neighbor_adapters: usize,
    /// GPUs those tenants hold on the shared islands (reported for
    /// diagnostics; the fabric pressure itself scales with adapters,
    /// whose optimizer collectives are what actually ride the switch).
    pub neighbor_gpus: usize,
}

impl ContentionCtx {
    /// No one else on the island — the legacy (uncontended) pricing.
    pub fn empty() -> ContentionCtx {
        ContentionCtx::default()
    }

    pub fn is_empty(&self) -> bool {
        self.neighbor_adapters == 0 && self.neighbor_gpus == 0
    }
}

/// Fabric pressure per foreign adapter slot: each claims ~1.5% of the
/// shared switch bandwidth (an 8-GPU island hosting 32 foreign adapter
/// slots halves a tenant's effective collective rate).
pub const FABRIC_PRESSURE_PER_ADAPTER: f64 = 0.015;

/// Slowdown ceiling: even a saturated island never derates a tenant's
/// collectives by more than this factor.
pub const MAX_FABRIC_SLOWDOWN: f64 = 2.0;

/// Multiplier (≥ 1) applied to a workload's collective time for the
/// given co-location context.  Exactly 1.0 for an empty context, and
/// monotone non-decreasing in `neighbor_adapters`.
pub fn fabric_slowdown(ctx: &ContentionCtx) -> f64 {
    (1.0 + FABRIC_PRESSURE_PER_ADAPTER * ctx.neighbor_adapters as f64).min(MAX_FABRIC_SLOWDOWN)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_context_is_free() {
        assert_eq!(fabric_slowdown(&ContentionCtx::empty()).to_bits(), 1.0f64.to_bits());
        assert!(ContentionCtx::default().is_empty());
    }

    #[test]
    fn slowdown_monotone_and_capped() {
        let mut last = 0.0;
        for n in 0..400 {
            let s = fabric_slowdown(&ContentionCtx {
                neighbor_adapters: n,
                neighbor_gpus: 0,
            });
            assert!(s >= 1.0);
            assert!(s >= last, "non-monotone at {n}: {s} < {last}");
            assert!(s <= MAX_FABRIC_SLOWDOWN);
            last = s;
        }
        // the cap binds eventually
        assert_eq!(
            fabric_slowdown(&ContentionCtx { neighbor_adapters: 1000, neighbor_gpus: 0 }),
            MAX_FABRIC_SLOWDOWN
        );
    }
}
