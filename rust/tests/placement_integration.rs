//! Integration over the concrete-placement layer: island-aware placement
//! vs topology-blind first-fit on a fragmentation-heavy 16-GPU trace —
//! both as a placement-only ablation (pricing off: identical clocks,
//! different indices) and with the perfmodel charging comm cost to the
//! clock (pricing on: island-aware placement strictly wins *makespan*) —
//! plus bitmap-consistent event logs, preemption/migration timelines,
//! and the golden digest + jsonl dump of a pinned (trace, seed).

use std::collections::BTreeMap;

use alto::cluster::{PlacePolicy, Placement};
use alto::config::TaskSpec;
use alto::coordinator::service::TaskOutcome;
use alto::sched::inter::{Policy, Pricing};
use alto::simharness::{EventKind, HarnessConfig, SimEngine, Trace};

fn engine_priced(
    total_gpus: usize,
    policy: Policy,
    place: PlacePolicy,
    preempt: bool,
    pricing: Pricing,
) -> SimEngine {
    SimEngine::new(HarnessConfig {
        total_gpus,
        policy,
        place,
        preempt_on_arrival: preempt,
        pricing,
        ..HarnessConfig::default()
    })
}

/// Legacy placement-blind clock: placement decides *which* GPUs, never
/// *how long* — the isolation baseline the timing-equality tests need.
fn engine(total_gpus: usize, policy: Policy, place: PlacePolicy, preempt: bool) -> SimEngine {
    engine_priced(total_gpus, policy, place, preempt, Pricing::none())
}

/// Hand-crafted outcome for replay-only tests: est == actual == `dur`.
fn outcome(name: &str, gpus: usize, dur: f64) -> TaskOutcome {
    TaskOutcome {
        name: name.into(),
        gpus,
        est_duration: dur,
        actual_duration: dur,
        best_val: 0.0,
        samples_used: 0,
        samples_budget: 0,
        saved_by_reason: BTreeMap::new(),
        group_slots: Vec::new(),
        group_results: Vec::new(),
    }
}

fn spec(gpus: usize, priority: i64) -> TaskSpec {
    TaskSpec {
        num_gpus: gpus,
        priority,
        ..TaskSpec::default()
    }
}

fn spec_model(model: &str, gpus: usize, priority: i64) -> TaskSpec {
    TaskSpec {
        model: model.into(),
        num_gpus: gpus,
        priority,
        ..TaskSpec::default()
    }
}

/// Walk an event log against an independent bitmap: every
/// placement-bearing event must allocate currently-free GPUs of exactly
/// the advertised width, and completes/preempts must release exactly
/// what the task held.  This re-derives the scheduler's bitmap from the
/// log alone — the "placements are consistent" acceptance check.
fn check_bitmap_consistency(log: &alto::simharness::EventLog, total_gpus: usize) {
    let mut free = vec![true; total_gpus];
    let mut held: BTreeMap<usize, Placement> = BTreeMap::new();
    for e in log.events() {
        match &e.kind {
            EventKind::Arrival { .. } => {}
            EventKind::Start { task, gpus, placement }
            | EventKind::Placed { task, gpus, placement } => {
                assert_eq!(placement.len(), *gpus, "event {e}");
                assert!(!held.contains_key(task), "task {task} started twice: {e}");
                for &g in placement.gpus() {
                    assert!(g < total_gpus, "GPU {g} out of range: {e}");
                    assert!(free[g], "GPU {g} double-booked: {e}");
                    free[g] = false;
                }
                held.insert(*task, placement.clone());
            }
            EventKind::Migrate { task, gpus, from, to } => {
                assert_eq!(to.len(), *gpus, "event {e}");
                assert!(!held.contains_key(task), "migrating task {task} still held: {e}");
                assert_ne!(from, to, "migrate with identical placement: {e}");
                for &g in to.gpus() {
                    assert!(free[g], "GPU {g} double-booked by migration: {e}");
                    free[g] = false;
                }
                held.insert(*task, to.clone());
            }
            EventKind::Complete { task, .. } | EventKind::Preempt { task, .. } => {
                let p = held
                    .remove(task)
                    .unwrap_or_else(|| panic!("task {task} released without holding: {e}"));
                if let EventKind::Preempt { placement, .. } = &e.kind {
                    assert_eq!(placement, &p, "preempt released wrong GPUs: {e}");
                }
                for &g in p.gpus() {
                    assert!(!free[g], "GPU {g} freed while free: {e}");
                    free[g] = true;
                }
            }
            EventKind::Reprice { task, .. } => {
                // re-pricing moves the clock, never the bitmap — but it
                // must only ever name a task that is currently running
                assert!(held.contains_key(task), "repriced a non-running task: {e}");
            }
            // body-level events and cluster-level fault/straggler marks
            // never move the bitmap
            EventKind::Segment { .. }
            | EventKind::JobExit { .. }
            | EventKind::Fail { .. }
            | EventKind::Recover { .. }
            | EventKind::Slowdown { .. }
            | EventKind::Restore { .. } => {}
            EventKind::Adopt { .. } | EventKind::Merge { .. } => {
                // shared-executor rosters alias one placement across
                // tasks; this walker checks exclusive ownership only
                panic!("walker does not model shared-executor groups: {e}")
            }
            EventKind::Evict { task, placement, .. } => {
                // `gpus` is the task's *requested* footprint (post-step
                // for rank-grow evictions) — only `placement` says what
                // was actually released, so free by that alone
                if placement.is_empty() {
                    assert!(!held.contains_key(task), "shed task {task} still held: {e}");
                } else {
                    let p = held
                        .remove(task)
                        .unwrap_or_else(|| panic!("task {task} evicted without holding: {e}"));
                    assert_eq!(placement, &p, "evict released wrong GPUs: {e}");
                    for &g in p.gpus() {
                        assert!(!free[g], "GPU {g} freed while free: {e}");
                        free[g] = true;
                    }
                }
            }
            EventKind::Resize { task, gpus, placement, .. } => {
                if placement.is_empty() {
                    // grow past the held placement: the paired rank-grow
                    // Evict (next in the log) releases the old GPUs
                    assert!(held.contains_key(task), "resized a non-running task: {e}");
                } else {
                    // in place or shrink: the new placement replaces the
                    // old (a prefix of it — free-then-claim checks that)
                    assert_eq!(placement.len(), *gpus, "event {e}");
                    let old = held
                        .remove(task)
                        .unwrap_or_else(|| panic!("task {task} resized without holding: {e}"));
                    for &g in old.gpus() {
                        assert!(!free[g], "GPU {g} freed while free: {e}");
                        free[g] = true;
                    }
                    for &g in placement.gpus() {
                        assert!(g < total_gpus, "GPU {g} out of range: {e}");
                        assert!(free[g], "GPU {g} double-booked by resize: {e}");
                        free[g] = false;
                    }
                    held.insert(*task, placement.clone());
                }
            }
        }
    }
    assert!(held.is_empty(), "timeline ended with live allocations: {held:?}");
    assert!(free.iter().all(|&f| f), "timeline ended with a dirty bitmap");
}

/// The placement-only ablation (pricing off), fully deterministic: a
/// 16-GPU two-island cluster fragments (scattered 1-GPU completions
/// leave 2 free GPUs on island 0 and 4 on island 1), then a 4-GPU task
/// arrives.  Topology-blind first-fit assembles the hole across both
/// islands; every island-aware policy keeps it inside island 1 —
/// strictly fewer cross-island allocations and strictly lower summed
/// comm cost, on an *identical* clock (the legacy baseline the priced
/// acceptance test below contrasts with).
#[test]
fn island_aware_beats_blind_first_fit_on_fragmented_cluster() {
    // 16 narrow tasks at t=0 fill the cluster one GPU each (task i on
    // GPU i under every policy); durations punch holes at {2,3} (t=100)
    // and {8,9,10,11} (t=150); the wide task lands at t=200.
    let mut pairs: Vec<(f64, TaskSpec)> = (0..16).map(|_| (0.0, spec(1, 0))).collect();
    pairs.push((200.0, spec(4, 0)));
    let trace = Trace::with_arrivals(pairs);
    let mut outcomes: Vec<TaskOutcome> = (0..16)
        .map(|i| {
            let dur = match i {
                2 | 3 => 100.0,
                8..=11 => 150.0,
                _ => 1000.0,
            };
            outcome(&format!("narrow-{i}"), 1, dur)
        })
        .collect();
    outcomes.push(outcome("wide", 4, 500.0));

    let blind = engine(16, Policy::Fcfs, PlacePolicy::FirstFit, false)
        .replay(&trace, &outcomes)
        .unwrap();
    assert_eq!(
        blind.placements[16].gpus(),
        &[2, 3, 8, 9],
        "first-fit should straddle the island boundary"
    );
    assert_eq!(blind.cross_island_allocs, 1);

    for place in [PlacePolicy::IslandFirst, PlacePolicy::BestFit, PlacePolicy::FragMin] {
        let aware = engine(16, Policy::Fcfs, place, false)
            .replay(&trace, &outcomes)
            .unwrap();
        assert_eq!(
            aware.placements[16].gpus(),
            &[8, 9, 10, 11],
            "{place:?} should fill island 1"
        );
        assert_eq!(aware.cross_island_allocs, 0, "{place:?}");
        assert!(
            aware.cross_island_allocs < blind.cross_island_allocs,
            "{place:?} must strictly beat blind first-fit"
        );
        assert!(
            aware.placement_comm_cost < blind.placement_comm_cost - 1e-12,
            "{place:?} comm cost {} must be strictly below blind {}",
            aware.placement_comm_cost,
            blind.placement_comm_cost
        );
        // placement choice never changes the clock
        assert_eq!(aware.makespan.to_bits(), blind.makespan.to_bits());
        check_bitmap_consistency(&aware.log, 16);
    }
    check_bitmap_consistency(&blind.log, 16);
}

/// The ISSUE acceptance scenario with the perfmodel *charging* comm cost
/// to the clock: the same fragmented 16-GPU heterogeneous trace, but the
/// wide task is a real 4-GPU 32B tenant whose per-step all-gathers
/// dominate once they ride the inter-island fabric.  Blind first-fit
/// assembles its hole across both islands and pays for it in wall time;
/// island-aware placement keeps it inside island 1 at full NVLink — so
/// topology-aware placement now strictly beats topology-blind first-fit
/// on **makespan**, not just on the reported comm score.  Replay of the
/// same (trace, outcomes) stays bit-identical, pricing included.
#[test]
fn charged_comm_cost_makes_island_aware_strictly_beat_blind_on_makespan() {
    // 16 narrow 1-GPU tenants at t=0 (task i lands on GPU i under every
    // policy); completions punch holes at {2,3} (t=100) and {8,9,10,11}
    // (t=150); the long 4-GPU 32B tenant arrives at t=200 and is the
    // critical path from then on.
    let mut pairs: Vec<(f64, TaskSpec)> = (0..16).map(|_| (0.0, spec(1, 0))).collect();
    pairs.push((200.0, spec_model("qwen-32b", 4, 0)));
    let trace = Trace::with_arrivals(pairs);
    let mut outcomes: Vec<TaskOutcome> = (0..16)
        .map(|i| {
            let dur = match i {
                2 | 3 => 100.0,
                8..=11 => 150.0,
                _ => 1000.0,
            };
            outcome(&format!("narrow-{i}"), 1, dur)
        })
        .collect();
    outcomes.push(outcome("wide", 4, 2000.0));

    // charge comm only: the factor is then a pure function of the
    // placement, which isolates exactly what the acceptance claims
    let charge = Pricing { comm: true, contention: false, migration: false };
    let blind = engine_priced(16, Policy::Fcfs, PlacePolicy::FirstFit, false, charge)
        .replay(&trace, &outcomes)
        .unwrap();
    let aware = engine_priced(16, Policy::Fcfs, PlacePolicy::IslandFirst, false, charge)
        .replay(&trace, &outcomes)
        .unwrap();

    // same placement decisions as the unpriced ablation...
    assert_eq!(blind.placements[16].gpus(), &[2, 3, 8, 9]);
    assert_eq!(aware.placements[16].gpus(), &[8, 9, 10, 11]);
    // ...but now the cross-island hole costs wall time: the single-island
    // run finishes exactly on the nominal clock (factor exactly 1.0)...
    assert_eq!(aware.makespan.to_bits(), 2200.0f64.to_bits());
    // ...while the blind run pays the derated fabric on every step
    assert!(
        blind.makespan > aware.makespan + 1.0,
        "topology-blind placement must lose makespan: blind {} vs aware {}",
        blind.makespan,
        aware.makespan
    );
    // GPU-seconds use charged (not nominal) durations: the blind run
    // burned strictly more cluster time for identical work
    assert!(
        blind.gpu_seconds > aware.gpu_seconds + 1.0,
        "charged GPU-seconds must reflect the comm cost: blind {} vs aware {}",
        blind.gpu_seconds,
        aware.gpu_seconds
    );
    // single-GPU tenants have no collectives: their clocks are untouched
    for tl in [&blind, &aware] {
        check_bitmap_consistency(&tl.log, 16);
        let narrow_completes: Vec<f64> = tl
            .log
            .events()
            .iter()
            .filter(|e| {
                matches!(e.kind, EventKind::Complete { task, .. } if task < 16)
            })
            .map(|e| e.time)
            .collect();
        assert_eq!(narrow_completes.len(), 16);
        assert!(narrow_completes.iter().all(|&t| t <= 1000.0 + 1e-9));
    }

    // replay of the same (trace, outcomes) is bit-identical, pricing
    // folded into the digest
    let again = engine_priced(16, Policy::Fcfs, PlacePolicy::FirstFit, false, charge)
        .replay(&trace, &outcomes)
        .unwrap();
    assert_eq!(again.log.digest(), blind.log.digest());
    assert_eq!(again.makespan.to_bits(), blind.makespan.to_bits());
}

/// The same comparison over the generated fragmentation-heavy workload,
/// end to end through the simulated task bodies: island-aware placement
/// never does worse than blind first-fit on either fragmentation metric.
#[test]
fn fragmentation_heavy_generator_aware_no_worse_than_blind() {
    let trace = Trace::fragmentation_heavy(16, 48, 7);
    let bodies = engine(16, Policy::Optimal, PlacePolicy::FirstFit, false)
        .simulate_trace(&trace)
        .unwrap();
    let blind = engine(16, Policy::Optimal, PlacePolicy::FirstFit, false)
        .replay(&trace, &bodies)
        .unwrap();
    let aware = engine(16, Policy::Optimal, PlacePolicy::IslandFirst, false)
        .replay(&trace, &bodies)
        .unwrap();
    assert!(
        aware.cross_island_allocs <= blind.cross_island_allocs,
        "aware {} vs blind {}",
        aware.cross_island_allocs,
        blind.cross_island_allocs
    );
    assert!(aware.placement_comm_cost <= blind.placement_comm_cost + 1e-9);
    // identical timing, different indices only
    assert_eq!(aware.makespan.to_bits(), blind.makespan.to_bits());
    for tl in [&blind, &aware] {
        assert_eq!(
            tl.log.count(|k| matches!(k, EventKind::Complete { .. })),
            trace.len()
        );
        check_bitmap_consistency(&tl.log, 16);
    }
}

/// Placements enabled and the perfmodel charging (the default), replay
/// stays a pure function of (cfg, trace): bit-identical event logs
/// (placement indices and reprice completions hashed) and every start
/// carries concrete, in-bounds, pairwise-disjoint GPU indices.
#[test]
fn replay_with_placements_is_bit_identical_and_consistent() {
    let trace = Trace::fragmentation_heavy(12, 48, 21);
    let a = engine_priced(16, Policy::Optimal, PlacePolicy::IslandFirst, false, Pricing::default())
        .run(&trace)
        .unwrap();
    let b = engine_priced(16, Policy::Optimal, PlacePolicy::IslandFirst, false, Pricing::default())
        .run(&trace)
        .unwrap();
    assert_eq!(a.log.digest(), b.log.digest(), "placement-bearing logs must replay bitwise");
    assert_eq!(a.log.events(), b.log.events());
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    // every start pins exactly gpus-many concrete indices
    let mut starts = 0;
    for e in a.log.events() {
        if let EventKind::Start { gpus, placement, .. } = &e.kind {
            starts += 1;
            assert_eq!(placement.len(), *gpus, "{e}");
        }
    }
    assert_eq!(starts, trace.len());
    check_bitmap_consistency(&a.log, 16);
    // final per-task placements are reported and sized
    assert_eq!(a.placements.len(), trace.len());
    for (i, p) in a.placements.iter().enumerate() {
        assert_eq!(p.len(), a.outcomes[i].gpus, "task {i}");
    }
}

/// Deterministic preemption/migration timeline (replay-only, pricing
/// off so the hand-computed timestamps stay exact): a priority-1
/// arrival evicts the youngest runner, which later resumes on different
/// GPUs — exercising Preempt, Start, Migrate and the remaining-duration
/// bookkeeping, with the bitmap consistent throughout.  (The charged
/// migration path is covered by `sched::inter`'s
/// `migration_pays_a_checkpoint_transfer_charge`.)
#[test]
fn preemption_evicts_youngest_and_migrates() {
    // 8 GPUs (one island). A: 4 GPUs, 30s. B: 4 GPUs, 18s. U arrives at
    // t=10 (priority 1, 4 GPUs, 50s) onto a full cluster.
    let trace = Trace::with_arrivals(vec![
        (0.0, spec(4, 0)),
        (0.0, spec(4, 0)),
        (10.0, spec(4, 1)),
    ]);
    let outcomes = vec![
        outcome("a", 4, 30.0),
        outcome("b", 4, 18.0),
        outcome("urgent", 4, 50.0),
    ];
    let tl = engine(8, Policy::Fcfs, PlacePolicy::IslandFirst, true)
        .replay(&trace, &outcomes)
        .unwrap();
    check_bitmap_consistency(&tl.log, 8);
    assert_eq!(tl.preemptions, 1);
    assert_eq!(tl.migrations, 1);
    let kinds: Vec<(&str, usize, f64)> = tl
        .log
        .events()
        .iter()
        .map(|e| {
            let label = match &e.kind {
                EventKind::Arrival { .. } => "arrive",
                EventKind::Start { .. } => "start",
                EventKind::Complete { .. } => "complete",
                EventKind::Preempt { .. } => "preempt",
                EventKind::Placed { .. } => "placed",
                EventKind::Migrate { .. } => "migrate",
                EventKind::Reprice { .. } => "reprice",
                _ => "other",
            };
            (label, e.kind.task(), e.time)
        })
        .collect();
    // t=10: B (the youngest tie-break: same start, higher id) is evicted
    // and U starts in its place
    assert!(kinds.contains(&("preempt", 1, 10.0)), "{kinds:?}");
    assert!(kinds.contains(&("start", 2, 10.0)), "{kinds:?}");
    // t=30: A completes, B resumes on A's freed GPUs → a migration
    assert!(kinds.contains(&("complete", 0, 30.0)), "{kinds:?}");
    assert!(kinds.contains(&("migrate", 1, 30.0)), "{kinds:?}");
    // B ran 10s before eviction, so it finishes 8s after resuming
    assert!(kinds.contains(&("complete", 1, 38.0)), "{kinds:?}");
    // U runs 10..60 uninterrupted
    assert!(kinds.contains(&("complete", 2, 60.0)), "{kinds:?}");
    assert_eq!(tl.makespan, 60.0);
    // the preempt event precedes the start it made room for
    let pre_seq = tl.log.events().iter().position(|e| matches!(e.kind, EventKind::Preempt { .. })).unwrap();
    let start_u = tl.log.events().iter().position(|e| matches!(&e.kind, EventKind::Start { task: 2, .. })).unwrap();
    assert!(pre_seq < start_u);

    // without preemption the urgent task queues behind the wave instead
    let no_pre = engine(8, Policy::Fcfs, PlacePolicy::IslandFirst, false)
        .replay(&trace, &outcomes)
        .unwrap();
    assert_eq!(no_pre.preemptions, 0);
    let urgent_start = |tl: &alto::simharness::Timeline| {
        tl.log
            .events()
            .iter()
            .find(|e| matches!(&e.kind, EventKind::Start { task: 2, .. }))
            .unwrap()
            .time
    };
    assert!(urgent_start(&tl) < urgent_start(&no_pre));
}

/// The generated preemption-stress workload through the full engine:
/// urgent arrivals land on a saturated cluster and evict; every task
/// still completes and the log replays the bitmap cleanly.
#[test]
fn preemption_stress_trace_evicts_and_completes() {
    // full default pricing: determinism must hold with contention
    // repricing and migration charges in the timeline
    let trace = Trace::preemption_stress(4, 4, 32, 3);
    let report = engine_priced(16, Policy::Fcfs, PlacePolicy::IslandFirst, true, Pricing::default())
        .run(&trace)
        .unwrap();
    assert!(report.preemptions >= 1, "urgent arrivals on a full cluster must evict");
    assert_eq!(
        report.log.count(|k| matches!(k, EventKind::Complete { .. })),
        trace.len()
    );
    assert_eq!(
        report.log.count(|k| matches!(k, EventKind::Preempt { .. })),
        report.preemptions
    );
    check_bitmap_consistency(&report.log, 16);
    // determinism holds under preemption + pricing too
    let again = engine_priced(16, Policy::Fcfs, PlacePolicy::IslandFirst, true, Pricing::default())
        .run(&trace)
        .unwrap();
    assert_eq!(report.log.digest(), again.log.digest());
}

/// Golden digest + jsonl dump for a pinned (trace, seed) under the
/// *default* (priced) configuration, so the pin guards the perfmodel's
/// charged clock — reprice completions included — not just placement
/// indices.
///
/// Self-arming: the first run on a fresh checkout writes
/// `rust/tests/golden/` (commit the result to arm the guard; CI arms and
/// immediately verifies it by running this test twice).  Later runs
/// compare bit-for-bit, so any placement/pricing/timing regression shows
/// up as a digest mismatch with a diffable jsonl next to it.
///
/// Re-arming after an *intentional* timing change (e.g. a perfmodel
/// constant): run once with `GOLDEN_UPDATE=1`, commit the regenerated
/// `rust/tests/golden/`, and say why in the commit message.  The
/// perfmodel refactor that charged comm cost and contention to the clock
/// invalidated any pre-perfmodel pin by design — goldens must be
/// regenerated from this revision onward.
#[test]
fn golden_event_log_digest_and_jsonl() {
    let trace = Trace::fragmentation_heavy(8, 32, 11);
    let report = engine_priced(
        16,
        Policy::Optimal,
        PlacePolicy::IslandFirst,
        false,
        Pricing::default(),
    )
    .run(&trace)
    .unwrap();
    let digest = format!("{:016x}", report.log.digest());
    let jsonl = report.log.to_jsonl();
    // jsonl round-trips bit-identically before we even touch the disk
    let back = alto::simharness::EventLog::from_jsonl(&jsonl).unwrap();
    assert_eq!(back.digest(), report.log.digest());

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden");
    let digest_path = dir.join("placement_event_log.digest");
    let jsonl_path = dir.join("placement_event_log.jsonl");
    let update = std::env::var("GOLDEN_UPDATE").map(|v| v == "1").unwrap_or(false);
    if update || !digest_path.exists() {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&digest_path, format!("{digest}\n")).unwrap();
        std::fs::write(&jsonl_path, &jsonl).unwrap();
        eprintln!("golden: pinned digest {digest} at {}", digest_path.display());
        return;
    }
    let pinned = std::fs::read_to_string(&digest_path).unwrap();
    assert_eq!(
        pinned.trim(),
        digest,
        "event-log digest drifted from the golden pin; diff {} and re-pin \
         with GOLDEN_UPDATE=1 if the change is intentional",
        jsonl_path.display()
    );
    // and the stored jsonl still parses to the same timeline
    let stored = std::fs::read_to_string(&jsonl_path).unwrap();
    let stored_log = alto::simharness::EventLog::from_jsonl(&stored).unwrap();
    assert_eq!(stored_log.digest(), report.log.digest());
}
