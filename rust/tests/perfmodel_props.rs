//! Property suite for the unified `perfmodel` layer — the invariants the
//! refactor promised:
//!
//! 1. step time is monotone non-decreasing in the co-located adapter
//!    count (island contention never speeds anyone up);
//! 2. step time is monotone non-decreasing in cross-island span, and
//!    strictly greater once a multi-GPU placement leaves its island;
//! 3. with an empty contention context and a single-island placement the
//!    model reproduces the legacy `Profiler::estimate_duration`
//!    arithmetic bit for bit (the refactor moved ownership, not
//!    numbers).

use alto::cluster::gpu::GpuSpec;
use alto::cluster::{Placement, Topology};
use alto::config::{SearchSpace, TaskSpec, MODEL_FAMILY};
use alto::coordinator::Profiler;
use alto::parallel::baselines::Alto;
use alto::parallel::workload::{Strategy, Workload};
use alto::perfmodel::{task_workload, ContentionCtx, StepTimeModel};
use alto::util::prop::{prop_assert, prop_check};

const MODELS: [&str; 4] = ["llama-8b", "qwen-7b", "qwen-32b", "llama-70b"];

fn random_workload(g: &mut alto::util::prop::Gen) -> Workload {
    let name = *g.choice(&MODELS);
    let n = g.usize(1..=8);
    let rank = *g.choice(&[8usize, 16, 32, 64]);
    Workload {
        model: MODEL_FAMILY.get(name).unwrap(),
        ranks: vec![rank; n],
        batch_per_adapter: *g.choice(&[1usize, 2, 4, 8]),
        seq_len: *g.choice(&[128usize, 256, 512]),
    }
}

#[test]
fn step_time_monotone_in_colocated_adapter_count() {
    prop_check("step time monotone in neighbor adapters", 150, |g| {
        let model = StepTimeModel::new(GpuSpec::h100_sxm5(), Topology::h100_nodes(16));
        let w = random_workload(g);
        let p_gpus = *g.choice(&[1usize, 2, 4, 8]);
        let gpus_held = g.usize(0..=4);
        let mut last = 0.0f64;
        for neighbors in 0..24usize {
            let ctx = ContentionCtx {
                neighbor_adapters: neighbors,
                neighbor_gpus: gpus_held,
            };
            let t = model.step_total(&w, p_gpus, None, &ctx);
            prop_assert(
                t.is_finite() && t > 0.0,
                format!("non-finite step time {t} at {neighbors} neighbors"),
            )?;
            prop_assert(
                t >= last,
                format!(
                    "{} adapters co-located must not speed p={p_gpus} up: {t} < {last}",
                    neighbors
                ),
            )?;
            last = t;
        }
        Ok(())
    });
}

#[test]
fn step_time_monotone_in_cross_island_span() {
    prop_check("step time monotone in islands spanned", 150, |g| {
        // 32 GPUs in 4-GPU islands: spans of 1..=4 islands are available
        // for a 4-GPU placement
        let topo = Topology::uniform(32, 4);
        let model = StepTimeModel::new(GpuSpec::h100_sxm5(), topo);
        let w = random_workload(g);
        // placements spanning exactly 1, 2, 3, 4 islands (4 GPUs each)
        let spans: [Placement; 4] = [
            Placement::new(vec![0, 1, 2, 3]),
            Placement::new(vec![0, 1, 4, 5]),
            Placement::new(vec![0, 1, 4, 8]),
            Placement::new(vec![0, 4, 8, 12]),
        ];
        let ctx = ContentionCtx::empty();
        let mut last = 0.0f64;
        for (i, p) in spans.iter().enumerate() {
            let t = model.step_total(&w, 4, Some(p), &ctx);
            prop_assert(
                t >= last,
                format!("hop {} must not be cheaper: {t} < {last}", i + 1),
            )?;
            last = t;
        }
        // leaving the island is strictly worse for any multi-GPU group
        let inside = model.step_total(&w, 4, Some(&spans[0]), &ctx);
        let across = model.step_total(&w, 4, Some(&spans[1]), &ctx);
        prop_assert(
            across > inside,
            format!("cross-island must cost strictly more: {across} vs {inside}"),
        )
    });
}

#[test]
fn uncontended_single_island_equals_legacy_profiler_bitwise() {
    prop_check("perfmodel == legacy Profiler path", 200, |g| {
        let name = *g.choice(&MODELS);
        let shape = MODEL_FAMILY.get(name).unwrap();
        let gpus = *g.choice(&[1usize, 2, 4]);
        let n_slots = g.usize(1..=8);
        let task = TaskSpec {
            model: name.into(),
            num_gpus: gpus,
            search_space: if g.bool() {
                SearchSpace::paper_single_gpu()
            } else {
                SearchSpace::paper_multi_gpu()
            },
            seq_len: *g.choice(&[128usize, 256, 512]),
            train_samples: g.usize(16..=4096),
            ..TaskSpec::default()
        };

        // the legacy arithmetic, inlined: dominant config through the
        // raw Alto strategy on the nominal device
        let gpu = GpuSpec::h100_sxm5();
        let w = task_workload(&shape, &task, n_slots);
        let t = Alto.step_time(&w, &gpu, gpus).total();
        let legacy =
            task.total_samples() as f64 / ((w.n_adapters() * w.batch_per_adapter) as f64 / t);

        // the perfmodel path, nominal
        let model = StepTimeModel::new(gpu.clone(), Topology::h100_nodes(16));
        let ctx = ContentionCtx::empty();
        let ours = model.estimate_task_duration(&shape, &task, n_slots, None, &ctx);
        prop_assert(
            ours.to_bits() == legacy.to_bits(),
            format!("nominal estimate drifted: {ours} vs legacy {legacy}"),
        )?;

        // ...and at any single-island placement of the right width
        let base = g.usize(0..=1) * 8; // island 0 or island 1
        let placed = Placement::new((base..base + gpus).collect());
        let at = model.estimate_task_duration(&shape, &task, n_slots, Some(&placed), &ctx);
        prop_assert(
            at.to_bits() == legacy.to_bits(),
            format!("single-island placement must be free: {at} vs {legacy}"),
        )?;

        // the caching facade agrees with the model it fronts
        let mut prof = Profiler::new(gpu);
        let cached = prof.estimate_duration(&shape, &task, n_slots);
        prop_assert(
            cached.to_bits() == legacy.to_bits(),
            format!("Profiler facade drifted: {cached} vs {legacy}"),
        )
    });
}

#[test]
fn charge_factor_bounds() {
    prop_check("charge factor is >= 1 and capped", 150, |g| {
        let model = StepTimeModel::new(GpuSpec::h100_sxm5(), Topology::h100_nodes(16));
        let w = random_workload(g);
        let p_gpus = *g.choice(&[1usize, 2, 4, 8]);
        let cross = g.bool();
        let placement = if cross {
            Placement::new(vec![6, 7, 8, 9])
        } else {
            Placement::new(vec![0, 1, 2, 3])
        };
        let ctx = ContentionCtx {
            neighbor_adapters: g.usize(0..=64),
            neighbor_gpus: g.usize(0..=12),
        };
        let f = model.charge_factor(&w, p_gpus, Some(&placement), &ctx);
        prop_assert(f.is_finite(), format!("factor {f}"))?;
        prop_assert(f >= 1.0, format!("pricing must never speed a task up: {f}"))?;
        // bounded: comm is one additive term derated at most 8× and
        // contended at most 2×, so the whole-step factor stays sane
        prop_assert(f < 64.0, format!("runaway factor {f}"))
    });
}

#[test]
fn group_stretch_identity_bounds_and_sublinearity() {
    // the shared-executor economics in one property: a roster identical
    // to your own is free (bitwise 1.0), a grown roster never speeds you
    // up, and duplicating the roster k× stretches the step *strictly*
    // less than k× (the roster-independent backbone stream + launch
    // overheads amortize) — which is exactly why cross-task adoption can
    // beat waiting for a dedicated allocation.
    prop_check("group stretch: identity, >= 1, strictly sublinear", 150, |g| {
        let model = StepTimeModel::new(GpuSpec::h100_sxm5(), Topology::h100_nodes(16));
        let own = random_workload(g);
        let p_gpus = *g.choice(&[1usize, 2, 4]);
        let s0 = model.group_stretch(&own, &own, p_gpus);
        prop_assert(
            s0.to_bits() == 1.0f64.to_bits(),
            format!("identical roster must stretch exactly 1.0, got {s0}"),
        )?;
        // grow the roster with arbitrary extra adapters: never a speedup
        let extra = g.usize(1..=6);
        let mut ranks = own.ranks.clone();
        for _ in 0..extra {
            ranks.push(*g.choice(&[8usize, 16, 32, 64]));
        }
        let grown = Workload { ranks, ..own.clone() };
        let s = model.group_stretch(&own, &grown, p_gpus);
        prop_assert(
            s.is_finite() && s >= 1.0,
            format!("grown roster stretch must be a finite factor >= 1, got {s}"),
        )?;
        // duplicate the whole roster k times: strictly sublinear
        let k = g.usize(2..=4);
        let dup = Workload {
            ranks: own.ranks.repeat(k),
            ..own.clone()
        };
        let sk = model.group_stretch(&own, &dup, p_gpus);
        prop_assert(
            sk >= 1.0 && sk < k as f64,
            format!("{k}x roster must stretch in [1, {k}), got {sk}"),
        )
    });
}
