//! Scheduling hot-path equivalence and regression suite.
//!
//! The PR that introduced incremental (dirty-set) re-pricing, the
//! completion-ordered index and the deep-queue anytime planner promised
//! one thing above all: *the speedup changes no simulated outcome*.
//! This suite pins that promise:
//!
//! * **Re-pricing equivalence** — the dirty-set scheduler drains
//!   bitwise-identical `RepriceDecision`s / `StartDecision`s /
//!   `PreemptDecision`s and charges bitwise-identical GPU-seconds
//!   against the retained full-recompute reference
//!   (`SchedTuning { incremental_reprice: false, .. }`), across
//!   fragmentation-heavy, preemption-stress and uniform-large traces
//!   and random seeds.
//! * **Engine-level digest equivalence** — a full simharness replay
//!   under the default tuning matches the
//!   [`SchedTuning::reference()`] replay bit for bit on shallow-queue
//!   traces (where the legacy planner and the optimized one are defined
//!   to coincide), pricing included.
//! * **Deep-queue solver regression** — `Policy::Optimal` on a 32+-task
//!   queue completes through the budgeted anytime path (no exponential
//!   blow-up), deterministically; the solver-level ≤-LPT guarantee and
//!   the budget-exhausted LPT fallback live in
//!   `rust/src/sched/solver.rs` unit tests.
//! * **Sharded event-core equivalence** — splitting the completion
//!   index by NVLink island group (`SchedTuning { shards: k }`) drains
//!   bit-identical decisions, makespans and charges against the single
//!   flat index across every trace family, policy and shard count,
//!   with and without preemption; the parallel price-factor gather
//!   engages (`parallel_reprice_batches > 0`) without perturbing a
//!   single bit; and the full streaming engine replays the same digest
//!   sharded, unsharded, and in digest-only (`retain_events: false`)
//!   mode.
//! * **Source-driven equivalence** — [`SimEngine::run_source`] over a
//!   lazy [`StreamingTrace`] (plus slab retirement) replays the
//!   materialized streaming digest bit for bit across every generator
//!   family and seed, and the source's running fingerprint equals the
//!   materialized trace's.
//! * **Coalesced-batch admission** — a forced same-timestamp wave
//!   admitted as one `submit_batch` (one replan) realizes the same
//!   start placements, makespan bits and charges as per-arrival
//!   `submit_spec` replans under FCFS (the order-preserving policy,
//!   where sequential greedy and batch greedy are defined to coincide).
//! * **Fault-plan no-op and equivalence** — an empty [`FaultPlan`]
//!   (even with a checkpoint interval set) plus an armed-but-idle
//!   overload config changes not one digest bit on any trace family;
//!   a seeded fault plan replays bit-identically across the batch,
//!   streaming and source-driven engine paths; and under GPU failures
//!   with overload off, every evicted runner is checkpoint-restored —
//!   no task is ever lost.
//! * **Dynamic rank reallocation** — an armed-but-never-firing
//!   [`RankPolicy`] (and the explicit [`RankPolicy::off`]) changes not
//!   one digest bit on any trace family; [`RankPolicy::paper`] on the
//!   rank-heavy trace replays bit-identically across all three engine
//!   paths with equal resize counters; every `Resize` event keeps an
//!   independently re-derived GPU bitmap consistent and the live
//!   footprint within capacity; and every rank-grow eviction
//!   checkpoint-restores — no task is ever lost to a resize.

use std::collections::BTreeMap;

use alto::cluster::gpu::GpuSpec;
use alto::cluster::{PlacePolicy, Placement, SimCluster, Topology};
use alto::config::MODEL_FAMILY;
use alto::coordinator::shared::SharingConfig;
use alto::perfmodel::{task_workload, ContentionCtx, StepTimeModel};
use alto::sched::inter::{
    EvictReason, InterTaskScheduler, OverloadConfig, Policy, PreemptDecision, Pricing,
    RepriceDecision, SchedTuning, StartDecision, Submission, TaskShape,
};
use alto::simharness::{
    uniform_mix, EventKind, FaultEvent, FaultPlan, HarnessConfig, RankPolicy, SimEngine,
    StreamingTrace, TimedFault, Trace,
};
use alto::util::rng::Pcg32;

/// Deterministic scheduler-level workload derived from a trace: worst
/// case estimates from the nominal perfmodel, actuals jittered below
/// them (the early-exit shape), pricing inputs from the spec.
fn submissions_from(trace: &Trace, seed: u64) -> Vec<Submission> {
    let model_nominal = StepTimeModel::nominal(GpuSpec::h100_sxm5());
    let mut rng = Pcg32::new(seed, 0x5ca1e);
    trace
        .entries
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let shape = MODEL_FAMILY
                .get(&e.spec.model)
                .expect("trace model exists");
            let est = model_nominal.estimate_task_duration(
                &shape,
                &e.spec,
                2,
                None,
                &ContentionCtx::empty(),
            );
            Submission {
                id: i,
                gpus: e.spec.num_gpus,
                est_duration: est,
                actual_duration: est * rng.uniform(0.3, 1.0),
                arrival: e.arrival,
                priority: e.spec.priority,
                shape: Some(TaskShape {
                    workload: task_workload(&shape, &e.spec, 2),
                    adapters: 2,
                    rank: e.spec.search_space.max_rank().max(1),
                }),
                ..Submission::default()
            }
        })
        .collect()
}

struct Drained {
    started: Vec<StartDecision>,
    preempted: Vec<PreemptDecision>,
    repriced: Vec<RepriceDecision>,
    makespan: f64,
    charged: f64,
    migration_charge: f64,
}

/// Drive the scheduler through the interleaved arrival/completion event
/// loop (the engine's discipline: completions win time ties), drain
/// every decision in order, and hand back the scheduler for
/// counter-level assertions.
fn drive_sched(
    subs: &[Submission],
    gpus: usize,
    island: usize,
    policy: Policy,
    preempt: bool,
    tuning: SchedTuning,
) -> (Drained, InterTaskScheduler) {
    let topo = Topology::uniform(gpus, island);
    let cluster = SimCluster::with_topology(GpuSpec::h100_sxm5(), topo.clone());
    let mut s = InterTaskScheduler::with_cluster(cluster, policy);
    s.place = PlacePolicy::IslandFirst;
    s.enable_preemption = preempt;
    s.tuning = tuning;
    s.set_pricer(
        StepTimeModel::new(GpuSpec::h100_sxm5(), topo),
        Pricing::default(),
    );
    let mut out = Drained {
        started: vec![],
        preempted: vec![],
        repriced: vec![],
        makespan: 0.0,
        charged: 0.0,
        migration_charge: 0.0,
    };
    let mut next = 0usize;
    loop {
        let arrival = subs.get(next).map(|s| s.arrival);
        let completion = s.peek_next_completion();
        let take_arrival = match (arrival, completion) {
            (None, None) => break,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some(at), Some((_, ct))) => at < ct,
        };
        if take_arrival {
            s.submit_spec(subs[next].clone())
                .expect("well-formed submission");
            next += 1;
        } else {
            s.complete_next()
                .expect("consistent scheduler state")
                .expect("peeked completion exists");
        }
        out.started.extend(s.drain_started());
        out.preempted.extend(s.drain_preempted());
        out.repriced.extend(s.drain_repriced());
    }
    assert!(s.all_done(), "driver left unfinished tasks");
    out.makespan = s.makespan();
    out.charged = s.charged_gpu_seconds();
    out.migration_charge = s.migration_charge;
    (out, s)
}

fn drive(
    subs: &[Submission],
    gpus: usize,
    island: usize,
    policy: Policy,
    preempt: bool,
    tuning: SchedTuning,
) -> Drained {
    drive_sched(subs, gpus, island, policy, preempt, tuning).0
}

fn assert_equivalent(a: &Drained, b: &Drained, label: &str) {
    assert_eq!(a.started, b.started, "{label}: start decisions drifted");
    assert_eq!(a.preempted, b.preempted, "{label}: preempt decisions drifted");
    assert_eq!(a.repriced, b.repriced, "{label}: reprice decisions drifted");
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{label}: makespan drifted ({} vs {})",
        a.makespan,
        b.makespan
    );
    assert_eq!(
        a.charged.to_bits(),
        b.charged.to_bits(),
        "{label}: charged GPU-seconds drifted ({} vs {})",
        a.charged,
        b.charged
    );
    assert_eq!(
        a.migration_charge.to_bits(),
        b.migration_charge.to_bits(),
        "{label}: migration charges drifted"
    );
}

/// Full-recompute reference that differs from the default tuning *only*
/// in the re-pricing scheme, so the comparison isolates the dirty-set
/// optimization (the deep-queue planner is pinned separately).
fn full_reprice() -> SchedTuning {
    SchedTuning {
        incremental_reprice: false,
        ..SchedTuning::default()
    }
}

#[test]
fn dirty_set_repricing_matches_full_recompute_on_fragmentation_traces() {
    let mut total_reprices = 0usize;
    for seed in [3u64, 7, 11] {
        let trace = Trace::fragmentation_heavy(20, 48, seed);
        let subs = submissions_from(&trace, seed);
        for policy in [Policy::Fcfs, Policy::Lpt, Policy::Optimal] {
            let fast = drive(&subs, 16, 8, policy, false, SchedTuning::default());
            let slow = drive(&subs, 16, 8, policy, false, full_reprice());
            total_reprices += fast.repriced.len();
            assert_equivalent(&fast, &slow, &format!("frag seed {seed} {policy:?}"));
        }
    }
    // dense all-at-zero cohorts guarantee co-residency even if the
    // spread-out traces above happened not to overlap
    let dense = Trace::at_zero(alto::simharness::frag_mix(12, 64, 5));
    let subs = submissions_from(&dense, 5);
    for policy in [Policy::Lpt, Policy::Optimal] {
        let fast = drive(&subs, 16, 8, policy, false, SchedTuning::default());
        let slow = drive(&subs, 16, 8, policy, false, full_reprice());
        total_reprices += fast.repriced.len();
        assert_equivalent(&fast, &slow, &format!("dense {policy:?}"));
    }
    assert!(
        total_reprices > 0,
        "the suite never exercised a reprice — the equivalence check is vacuous"
    );
}

#[test]
fn dirty_set_repricing_matches_full_recompute_under_preemption() {
    for seed in [5u64, 9] {
        let trace = Trace::preemption_stress(4, 6, 64, seed);
        let subs = submissions_from(&trace, seed);
        for policy in [Policy::Fcfs, Policy::Optimal] {
            let fast = drive(&subs, 16, 8, policy, true, SchedTuning::default());
            let slow = drive(&subs, 16, 8, policy, true, full_reprice());
            assert!(
                !fast.preempted.is_empty(),
                "seed {seed}: stress trace must preempt"
            );
            assert_equivalent(&fast, &slow, &format!("preempt seed {seed} {policy:?}"));
        }
    }
}

#[test]
fn dirty_set_repricing_matches_full_recompute_on_uniform_large() {
    // 60 single-GPU tenants on tight arrivals (offered load > 1, so the
    // queue builds up): both tunings route through the same plan path
    // while only the re-pricing scheme differs
    let trace = Trace::uniform_large(60, 48, 1.0, 13);
    let subs = submissions_from(&trace, 13);
    for policy in [Policy::Lpt, Policy::Optimal] {
        let fast = drive(&subs, 16, 8, policy, false, SchedTuning::default());
        let slow = drive(&subs, 16, 8, policy, false, full_reprice());
        assert_equivalent(&fast, &slow, &format!("uniform {policy:?}"));
    }
}

#[test]
fn engine_replay_digest_identical_between_default_and_reference_tuning() {
    // the golden-trace shape (shallow queues: ≤ 8 waiting): the
    // optimized scheduler is *defined* to be bit-identical to the
    // pre-optimization reference here, pricing included
    let trace = Trace::fragmentation_heavy(8, 32, 11);
    let base = HarnessConfig {
        total_gpus: 16,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        ..HarnessConfig::default()
    };
    let engine = SimEngine::new(base.clone());
    let bodies = engine.simulate_trace(&trace).unwrap();
    let fast = engine.replay(&trace, &bodies).unwrap();
    let reference = SimEngine::new(HarnessConfig {
        tuning: SchedTuning::reference(),
        ..base
    })
    .replay(&trace, &bodies)
    .unwrap();
    assert_eq!(
        fast.log.digest(),
        reference.log.digest(),
        "optimized replay drifted from the pre-optimization reference"
    );
    assert_eq!(fast.makespan.to_bits(), reference.makespan.to_bits());
    assert_eq!(fast.gpu_seconds.to_bits(), reference.gpu_seconds.to_bits());
    assert_eq!(fast.reprices, reference.reprices);
}

#[test]
fn sharing_is_deterministic_invisible_when_off_and_saves_when_on() {
    // a saturated co-locatable stream at the raw scheduler level: 30
    // same-family 1-GPU tenants pounding 4 GPUs
    let trace = Trace::colocatable(30, 6, 48, 1.0, 19);
    let subs = submissions_from(&trace, 19);
    let run = |sharing: Option<SharingConfig>| {
        let topo = Topology::uniform(4, 8);
        let cluster = SimCluster::with_topology(GpuSpec::h100_sxm5(), topo.clone());
        let mut s = InterTaskScheduler::with_cluster(cluster, Policy::Optimal);
        s.set_pricer(
            StepTimeModel::new(GpuSpec::h100_sxm5(), topo),
            Pricing::default(),
        );
        if let Some(cfg) = sharing {
            s.set_sharing(cfg);
        }
        let mut next = 0usize;
        loop {
            let arrival = subs.get(next).map(|s| s.arrival);
            let completion = s.peek_next_completion();
            let take_arrival = match (arrival, completion) {
                (None, None) => break,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (Some(at), Some((_, ct))) => at < ct,
            };
            if take_arrival {
                s.submit_spec(subs[next].clone()).unwrap();
                next += 1;
            } else {
                s.complete_next().unwrap().unwrap();
            }
        }
        assert!(s.all_done());
        (s.makespan(), s.charged_gpu_seconds(), s.adoptions, s.merges)
    };
    // configuring sharing disabled is bitwise the never-configured path
    let never = run(None);
    let off = run(Some(SharingConfig::default()));
    assert_eq!(off.0.to_bits(), never.0.to_bits());
    assert_eq!(off.1.to_bits(), never.1.to_bits());
    assert_eq!(off.2, 0);
    assert_eq!(never.2, 0);
    // sharing on is deterministic run to run...
    let on = run(Some(SharingConfig::paper()));
    let on2 = run(Some(SharingConfig::paper()));
    assert_eq!(on.0.to_bits(), on2.0.to_bits());
    assert_eq!(on.1.to_bits(), on2.1.to_bits());
    assert_eq!(on.2, on2.2);
    assert_eq!(on.3, on2.3);
    // ...and strictly wins on this workload
    assert!(on.2 > 0, "saturated co-locatable stream must adopt");
    assert!(
        on.0 < off.0,
        "sharing must shorten the makespan: {} vs {}",
        on.0,
        off.0
    );
    assert!(
        on.1 < off.1,
        "sharing must cut charged GPU-seconds: {} vs {}",
        on.1,
        off.1
    );
}

#[test]
fn deep_queue_optimal_completes_fast_and_reuses_cached_plans() {
    // 200 long tenants pounding a 32-GPU cluster (offered load ≫ 1, so
    // the waiting set grows into the hundreds): the pre-optimization
    // scheduler's exact replan was exponential here; the anytime path
    // must stay interactive and reuse the surviving plan prefix on
    // completion-triggered replans
    let model = MODEL_FAMILY.get("llama-8b").unwrap();
    let mut rng = Pcg32::new(21, 0xdee9);
    let mut subs: Vec<Submission> = Vec::with_capacity(200);
    let mut at = 0.0;
    for i in 0..200usize {
        at += -5.0 * (1.0 - rng.f64()).ln(); // Poisson, 5 s mean gap
        let gpus = *rng.choice(&[1usize, 1, 1, 2, 4]);
        let d = rng.uniform(200.0, 800.0);
        subs.push(Submission {
            id: i,
            gpus,
            est_duration: d,
            actual_duration: d * rng.uniform(0.5, 1.0),
            arrival: at,
            priority: 0,
            shape: Some(TaskShape {
                workload: alto::parallel::workload::Workload {
                    model: model.clone(),
                    ranks: vec![16; 2],
                    batch_per_adapter: 2,
                    seq_len: 256,
                },
                adapters: 2,
                rank: 16,
            }),
            ..Submission::default()
        });
    }
    let t0 = std::time::Instant::now();
    let topo = Topology::uniform(32, 8);
    let cluster = SimCluster::with_topology(GpuSpec::h100_sxm5(), topo.clone());
    let mut s = InterTaskScheduler::with_cluster(cluster, Policy::Optimal);
    s.set_pricer(
        StepTimeModel::new(GpuSpec::h100_sxm5(), topo),
        Pricing::default(),
    );
    let mut next = 0usize;
    loop {
        let arrival = subs.get(next).map(|s| s.arrival);
        let completion = s.peek_next_completion();
        let take_arrival = match (arrival, completion) {
            (None, None) => break,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some(at), Some((_, ct))) => at < ct,
        };
        if take_arrival {
            s.submit_spec(subs[next].clone()).unwrap();
            next += 1;
        } else {
            s.complete_next().unwrap().unwrap();
        }
    }
    assert!(s.all_done());
    assert!(s.deep_plans > 0, "200 long tenants must exceed the deep threshold");
    assert!(
        s.deep_solves < s.deep_plans,
        "completion replans must reuse the cached order ({} solves / {} deep plans)",
        s.deep_solves,
        s.deep_plans
    );
    let elapsed = t0.elapsed();
    // generous for debug builds; the pre-optimization scheduler would
    // not finish this run at all (exponential replans)
    assert!(
        elapsed.as_secs() < 60,
        "deep-queue run took {elapsed:?}; the anytime path has regressed"
    );
}

/// Tuning that differs from the default *only* in the shard count, so
/// the comparison isolates the sharded completion index.
fn sharded(shards: usize) -> SchedTuning {
    SchedTuning {
        shards,
        ..SchedTuning::default()
    }
}

#[test]
fn sharded_completion_index_matches_flat_index_across_trace_families() {
    // 32 GPUs in 4-wide islands → 8 islands, so shard counts {2, 8}
    // exercise both the merged-islands mapping and one-shard-per-island
    let cases: Vec<(&str, Vec<Submission>, bool)> = vec![
        (
            "frag",
            submissions_from(&Trace::fragmentation_heavy(20, 48, 3), 3),
            false,
        ),
        (
            "preempt",
            submissions_from(&Trace::preemption_stress(4, 6, 64, 9), 9),
            true,
        ),
        (
            "uniform",
            submissions_from(&Trace::uniform_large(60, 48, 1.0, 13), 13),
            false,
        ),
        (
            "coloc",
            submissions_from(&Trace::colocatable(30, 6, 48, 1.0, 19), 19),
            false,
        ),
    ];
    for (label, subs, preempt) in &cases {
        for policy in [Policy::Fcfs, Policy::Optimal] {
            let flat = drive(subs, 32, 4, policy, *preempt, SchedTuning::default());
            for shards in [2usize, 8, 64] {
                let shd = drive(subs, 32, 4, policy, *preempt, sharded(shards));
                assert_equivalent(
                    &shd,
                    &flat,
                    &format!("{label} {policy:?} shards={shards}"),
                );
            }
        }
    }
}

#[test]
fn parallel_price_gather_engages_and_changes_no_bits() {
    // saturated 1-GPU tenants keep the running set wide, so full
    // reprices batch enough factor computations to cross the (forced)
    // parallel threshold on every pass
    let subs = submissions_from(&Trace::uniform_large(60, 48, 1.0, 13), 13);
    for policy in [Policy::Lpt, Policy::Optimal] {
        let flat = drive(&subs, 32, 4, policy, false, SchedTuning::default());
        let tuning = SchedTuning {
            shards: 8,
            parallel_reprice_min: 1,
            ..SchedTuning::default()
        };
        let (par, sched) = drive_sched(&subs, 32, 4, policy, false, tuning);
        assert!(
            sched.parallel_reprice_batches > 0,
            "{policy:?}: the parallel gather never engaged — the bitwise check is vacuous"
        );
        assert_equivalent(&par, &flat, &format!("parallel gather {policy:?}"));
    }
}

#[test]
fn sharded_streaming_engine_replays_the_flat_digest() {
    // whole-engine check: event loop + parallel body prefetch + sharded
    // scheduler against the stock single-loop configuration
    let trace = Trace::duplicate_heavy(60, 12, 48, 1.0, 42);
    let base = HarnessConfig {
        total_gpus: 32,
        island_size: 4,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        ..HarnessConfig::default()
    };
    let flat = SimEngine::new(base.clone()).run_streaming(&trace).unwrap();
    let shard_cfg = HarnessConfig {
        tuning: SchedTuning {
            shards: 8,
            parallel_reprice_min: 1,
            ..SchedTuning::default()
        },
        ..base.clone()
    };
    let shd = SimEngine::new(shard_cfg.clone()).run_streaming(&trace).unwrap();
    assert_eq!(
        shd.timeline.log.digest(),
        flat.timeline.log.digest(),
        "sharded streaming run drifted from the single-loop digest"
    );
    assert_eq!(shd.timeline.makespan.to_bits(), flat.timeline.makespan.to_bits());
    assert_eq!(shd.timeline.gpu_seconds.to_bits(), flat.timeline.gpu_seconds.to_bits());
    assert_eq!(shd.timeline.placements, flat.timeline.placements);
    // the sharded engine pre-simulates every distinct body in parallel,
    // so the lazy resolver serves each start from the memo
    assert_eq!(shd.distinct_bodies, flat.distinct_bodies);

    // digest-only retention folds the same timeline without holding it
    let lean = SimEngine::new(HarnessConfig {
        retain_events: false,
        ..shard_cfg
    })
    .run_streaming(&trace)
    .unwrap();
    assert_eq!(
        lean.timeline.log.digest(),
        flat.timeline.log.digest(),
        "digest-only mode drifted from the retained timeline"
    );
    assert_eq!(lean.timeline.log.len(), flat.timeline.log.len());
    assert_eq!(lean.timeline.log.retained(), 0);
    assert!(lean.timeline.log.events().is_empty());
    assert!(flat.timeline.log.retained() > 0);
}

#[test]
fn source_driven_engine_matches_streaming_across_generators() {
    // the 1M-mode contract: a lazy StreamingTrace fed through
    // `run_source` (slab retirement on, digest-only retention) replays
    // the materialized streaming timeline bit for bit, for every
    // generator family and seed, and its running fingerprint lands on
    // the materialized trace's
    let base = HarnessConfig {
        total_gpus: 16,
        island_size: 8,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        retain_events: false,
        ..HarnessConfig::default()
    };
    for seed in [3u64, 11] {
        let cases: Vec<(&str, Trace, StreamingTrace, bool)> = vec![
            (
                "uniform",
                Trace::uniform_large(12, 32, 40.0, seed),
                StreamingTrace::uniform_large(12, 32, 40.0, seed),
                false,
            ),
            (
                "duplicate",
                Trace::duplicate_heavy(12, 3, 32, 40.0, seed),
                StreamingTrace::duplicate_heavy(12, 3, 32, 40.0, seed),
                false,
            ),
            (
                "coloc",
                Trace::colocatable(12, 3, 32, 40.0, seed),
                StreamingTrace::colocatable(12, 3, 32, 40.0, seed),
                false,
            ),
            (
                "frag",
                Trace::fragmentation_heavy(10, 32, seed),
                StreamingTrace::fragmentation_heavy(10, 32, seed),
                false,
            ),
            // the t = 0 wave shares one exact timestamp, so this family
            // also exercises the coalesced-batch admission on both
            // sides, with evictions in the mix
            (
                "preempt",
                Trace::preemption_stress(3, 4, 32, seed),
                StreamingTrace::preemption_stress(3, 4, 32, seed),
                true,
            ),
        ];
        for (label, trace, mut src, preempt) in cases {
            let engine = SimEngine::new(HarnessConfig {
                preempt_on_arrival: preempt,
                ..base.clone()
            });
            let full = engine.run_streaming(&trace).unwrap();
            let lean = engine.run_source(&mut src).unwrap();
            let tag = format!("{label} seed {seed}");
            assert_eq!(
                lean.fingerprint,
                trace.fingerprint(),
                "{tag}: lazy source drifted from the materialized trace"
            );
            assert_eq!(
                lean.log.digest(),
                full.timeline.log.digest(),
                "{tag}: source-driven digest drifted from streaming"
            );
            assert_eq!(lean.log.len(), full.timeline.log.len(), "{tag}");
            assert_eq!(
                lean.makespan.to_bits(),
                full.timeline.makespan.to_bits(),
                "{tag}: makespan drifted"
            );
            assert_eq!(lean.tasks, trace.len(), "{tag}");
            assert_eq!(lean.replans, full.timeline.replans, "{tag}");
            assert_eq!(lean.reprices, full.timeline.reprices, "{tag}");
            assert_eq!(lean.distinct_bodies, full.distinct_bodies, "{tag}");
            assert_eq!(lean.memo_hits, full.memo_hits, "{tag}");
        }
    }
}

#[test]
fn source_driven_engine_matches_streaming_at_1k_scale() {
    // the mid-scale point (the bench asserts the same equality at 100k
    // in release mode, in-process): duplicate-heavy so the 1k bodies
    // collapse onto 8 distinct simulations, offered load below 1 on
    // 128 GPUs so the live window stays bounded — the regime the
    // O(live) claim is about
    let trace = Trace::duplicate_heavy(1_000, 8, 24, 6.0, 42);
    let mut src = StreamingTrace::duplicate_heavy(1_000, 8, 24, 6.0, 42);
    let engine = SimEngine::new(HarnessConfig {
        total_gpus: 128,
        island_size: 8,
        retain_events: false,
        ..HarnessConfig::default()
    });
    let full = engine.run_streaming(&trace).unwrap();
    let lean = engine.run_source(&mut src).unwrap();
    assert_eq!(lean.fingerprint, trace.fingerprint());
    assert_eq!(lean.log.digest(), full.timeline.log.digest());
    assert_eq!(lean.log.len(), full.timeline.log.len());
    assert_eq!(lean.makespan.to_bits(), full.timeline.makespan.to_bits());
    assert_eq!(lean.tasks, 1_000);
    assert_eq!(lean.log.retained(), 0);
}

#[test]
fn trace_cursor_feeds_run_source_identically() {
    // any held Trace can be streamed through the source loop via its
    // cursor — same digest, same fingerprint, nothing rematerialized
    let trace = Trace::fragmentation_heavy(8, 32, 11);
    let engine = SimEngine::new(HarnessConfig {
        total_gpus: 16,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        ..HarnessConfig::default()
    });
    let full = engine.run_streaming(&trace).unwrap();
    let lean = engine.run_source(&mut trace.source()).unwrap();
    assert_eq!(lean.fingerprint, trace.fingerprint());
    assert_eq!(lean.log.digest(), full.timeline.log.digest());
    assert_eq!(lean.makespan.to_bits(), full.timeline.makespan.to_bits());
    assert_eq!(lean.tasks, trace.len());
}

/// Drive the scheduler by admitting the whole same-timestamp wave as
/// one `submit_batch` (one replan), then draining completions — the
/// engine's coalesced fast path, reproduced at the scheduler level.
fn drive_batch(
    subs: &[Submission],
    gpus: usize,
    island: usize,
    policy: Policy,
) -> (Drained, usize) {
    let topo = Topology::uniform(gpus, island);
    let cluster = SimCluster::with_topology(GpuSpec::h100_sxm5(), topo.clone());
    let mut s = InterTaskScheduler::with_cluster(cluster, policy);
    s.place = PlacePolicy::IslandFirst;
    s.set_pricer(
        StepTimeModel::new(GpuSpec::h100_sxm5(), topo),
        Pricing::default(),
    );
    s.submit_batch(subs.to_vec()).expect("well-formed batch");
    let mut out = Drained {
        started: vec![],
        preempted: vec![],
        repriced: vec![],
        makespan: 0.0,
        charged: 0.0,
        migration_charge: 0.0,
    };
    loop {
        out.started.extend(s.drain_started());
        out.preempted.extend(s.drain_preempted());
        out.repriced.extend(s.drain_repriced());
        if s.complete_next().expect("consistent scheduler state").is_none() {
            break;
        }
    }
    assert!(s.all_done(), "batch driver left unfinished tasks");
    out.makespan = s.makespan();
    out.charged = s.charged_gpu_seconds();
    out.migration_charge = s.migration_charge;
    (out, s.replans)
}

#[test]
fn coalesced_batch_admission_matches_sequential_fcfs_outcomes() {
    // a forced same-timestamp wave: every arrival at the bit-equal
    // t = 0.0 the engine now admits as one coalesced batch.  Under FCFS
    // the plan order is (arrival, id) either way, so incremental greedy
    // admission (one replan per submission) and batch greedy admission
    // (one replan for the wave) are *defined* to realize the same
    // placements; and because zero wall time elapses between the
    // sequential starts, every intermediate reprice folds zero progress
    // and lands on the exact `clock + charge + remaining × factor` the
    // batch pricing computes — so makespan and charges must agree bit
    // for bit, not just approximately.  (Duration-ordered policies
    // reorder inside a batch by design, so only FCFS is pinned.)
    //
    // Event *interleaving* differs by design — the sequential path
    // interleaves Starts between same-time Arrivals and emits the
    // intermediate zero-progress reprices — so the comparison is
    // outcome-level, not digest-level.
    for seed in [5u64, 17] {
        let wave = Trace::at_zero(alto::simharness::frag_mix(12, 48, seed));
        let subs = submissions_from(&wave, seed);
        assert!(
            subs.iter().all(|s| s.arrival.to_bits() == 0.0_f64.to_bits()),
            "the wave must share one exact timestamp"
        );
        let (seq, seq_sched) =
            drive_sched(&subs, 16, 8, Policy::Fcfs, false, SchedTuning::default());
        let (batch, batch_replans) = drive_batch(&subs, 16, 8, Policy::Fcfs);
        let tag = format!("coalesced wave seed {seed}");
        assert_eq!(batch.started, seq.started, "{tag}: start decisions drifted");
        assert_eq!(batch.preempted, seq.preempted, "{tag}");
        assert_eq!(
            batch.makespan.to_bits(),
            seq.makespan.to_bits(),
            "{tag}: makespan drifted ({} vs {})",
            batch.makespan,
            seq.makespan
        );
        assert_eq!(
            batch.charged.to_bits(),
            seq.charged.to_bits(),
            "{tag}: charged GPU-seconds drifted ({} vs {})",
            batch.charged,
            seq.charged
        );
        assert_eq!(
            batch.migration_charge.to_bits(),
            seq.migration_charge.to_bits(),
            "{tag}: migration charges drifted"
        );
        assert!(
            batch_replans < seq_sched.replans,
            "{tag}: the batch path must replan less than per-arrival \
             admission ({batch_replans} vs {})",
            seq_sched.replans
        );
    }
}

#[test]
fn empty_fault_plan_and_idle_overload_change_no_digest_bits() {
    // the no-op contract: an empty fault plan — even with a checkpoint
    // interval configured — and an enabled-but-never-triggered overload
    // config replay every trace family bit-identically to the default
    // (fault-free, overload-off) configuration
    let base = HarnessConfig {
        total_gpus: 16,
        island_size: 8,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        ..HarnessConfig::default()
    };
    for seed in [3u64, 11] {
        let cases: Vec<(&str, Trace, bool)> = vec![
            ("uniform", Trace::uniform_large(12, 32, 40.0, seed), false),
            ("frag", Trace::fragmentation_heavy(10, 32, seed), false),
            ("preempt", Trace::preemption_stress(3, 4, 32, seed), true),
            ("bursty", Trace::bursty_uniform(16, 32, 4, 200.0, seed), false),
            (
                "diurnal",
                Trace::diurnal_uniform(16, 32, 20.0, 200.0, 2000.0, seed),
                false,
            ),
        ];
        for (label, trace, preempt) in cases {
            let cfg = HarnessConfig {
                preempt_on_arrival: preempt,
                ..base.clone()
            };
            let clean = SimEngine::new(cfg.clone()).run_streaming(&trace).unwrap();
            let idle = SimEngine::new(HarnessConfig {
                faults: FaultPlan::none().with_checkpoint_interval(120.0),
                overload: OverloadConfig {
                    enabled: true,
                    pressure_threshold: 1_000_000,
                },
                ..cfg
            })
            .run_streaming(&trace)
            .unwrap();
            let tag = format!("{label} seed {seed}");
            assert_eq!(
                idle.timeline.log.digest(),
                clean.timeline.log.digest(),
                "{tag}: idle fault/overload machinery perturbed the digest"
            );
            assert_eq!(
                idle.timeline.makespan.to_bits(),
                clean.timeline.makespan.to_bits(),
                "{tag}: makespan drifted"
            );
            assert_eq!(
                idle.timeline.gpu_seconds.to_bits(),
                clean.timeline.gpu_seconds.to_bits(),
                "{tag}: charged GPU-seconds drifted"
            );
            assert_eq!(idle.timeline.log.len(), clean.timeline.log.len(), "{tag}");
            assert_eq!(idle.timeline.fault_evictions, 0, "{tag}");
            assert_eq!(idle.timeline.sheds, 0, "{tag}");
        }
    }
}

#[test]
fn seeded_fault_plan_replays_identically_across_all_three_engine_paths() {
    // the replay contract under injected faults: batch `run`, streaming
    // and the lazy source-driven loop fold Fail / Recover / Slowdown /
    // Restore / Evict events into bit-identical digests
    for seed in [3u64, 11] {
        let faults = FaultPlan::seeded(16, 8, 400.0, 3, 2, seed).with_checkpoint_interval(45.0);
        let cfg = HarnessConfig {
            total_gpus: 16,
            island_size: 8,
            policy: Policy::Optimal,
            place: PlacePolicy::IslandFirst,
            faults,
            ..HarnessConfig::default()
        };
        let trace = Trace::uniform_large(24, 32, 5.0, seed);
        let mut src = StreamingTrace::uniform_large(24, 32, 5.0, seed);
        let engine = SimEngine::new(cfg);
        let batch = engine.run(&trace).unwrap();
        let stream = engine.run_streaming(&trace).unwrap();
        let lean = engine.run_source(&mut src).unwrap();
        let tag = format!("seed {seed}");
        assert_eq!(
            stream.timeline.log.digest(),
            batch.log.digest(),
            "{tag}: streaming drifted from batch under faults"
        );
        assert_eq!(
            lean.log.digest(),
            batch.log.digest(),
            "{tag}: source-driven drifted from batch under faults"
        );
        assert_eq!(stream.timeline.log.len(), batch.log.len(), "{tag}");
        assert_eq!(lean.log.len(), batch.log.len(), "{tag}");
        assert_eq!(
            stream.timeline.makespan.to_bits(),
            batch.makespan.to_bits(),
            "{tag}: makespan drifted"
        );
        assert_eq!(lean.makespan.to_bits(), batch.makespan.to_bits(), "{tag}");
        assert_eq!(
            stream.timeline.fault_evictions, batch.fault_evictions,
            "{tag}: eviction counts drifted"
        );
        assert_eq!(lean.fault_evictions, batch.fault_evictions, "{tag}");
        assert_eq!(lean.tasks, trace.len(), "{tag}");
        // the plan's Fail events always reach the log, so the fault
        // machinery demonstrably engaged even if no runner was hit
        let fails = batch
            .log
            .events()
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Fail { .. }))
            .count();
        assert_eq!(fails, 3, "{tag}: seeded plan must inject 3 failures");
    }
}

#[test]
fn failed_runners_are_checkpoint_restored_and_no_task_is_lost() {
    // conservation: a dense t = 0 wave keeps all 16 GPUs busy, so the
    // early GPU failures are guaranteed to evict live runners; with
    // overload off, every victim must checkpoint-restore and complete
    let trace = Trace::at_zero(uniform_mix(60, 48, 23));
    let faults = FaultPlan::new(vec![
        TimedFault {
            time: 1.0,
            event: FaultEvent::GpuFail { gpu: 3 },
        },
        TimedFault {
            time: 2.0,
            event: FaultEvent::GpuFail { gpu: 11 },
        },
        TimedFault {
            time: 1.0e5,
            event: FaultEvent::GpuRecover { gpu: 3 },
        },
        TimedFault {
            time: 2.0e5,
            event: FaultEvent::GpuRecover { gpu: 11 },
        },
    ])
    .with_checkpoint_interval(60.0);
    let engine = SimEngine::new(HarnessConfig {
        total_gpus: 16,
        island_size: 8,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        faults,
        ..HarnessConfig::default()
    });
    let report = engine.run_streaming(&trace).unwrap();
    let tl = &report.timeline;
    let (mut completes, mut evicts) = (0usize, 0usize);
    for e in tl.log.events() {
        match &e.kind {
            EventKind::Complete { .. } => completes += 1,
            EventKind::Evict { reason, .. } => {
                assert_eq!(
                    *reason,
                    EvictReason::GpuFail,
                    "overload is off: only gpu-fail evictions may occur"
                );
                evicts += 1;
            }
            _ => {}
        }
    }
    assert_eq!(completes, trace.len(), "a task was lost to the failure");
    assert!(
        evicts >= 2,
        "two failures on a saturated cluster must evict at least their runners"
    );
    assert_eq!(evicts, tl.fault_evictions, "counter / event-log mismatch");
    assert_eq!(tl.sheds, 0);
    assert_eq!(tl.deadline_misses, 0);
    for s in &report.summaries {
        assert!(
            s.actual_duration.is_finite(),
            "task '{}' never resolved — it was shed, not restored",
            s.name
        );
    }
}

/// Re-derive the GPU bitmap from an event log alone, resize events
/// included: every allocation must claim free in-range GPUs, every
/// release must free exactly what its task holds — by `placement`
/// payloads, never by `gpus` (a rank-grow eviction's `gpus` is already
/// the *post-step* footprint while its `placement` is the old one) —
/// and the live footprint can never exceed capacity.
fn walk_rank_bitmap(log: &alto::simharness::EventLog, total_gpus: usize) {
    let mut free = vec![true; total_gpus];
    let mut held: BTreeMap<usize, Placement> = BTreeMap::new();
    for e in log.events() {
        match &e.kind {
            EventKind::Arrival { .. } => {}
            EventKind::Start { task, gpus, placement }
            | EventKind::Placed { task, gpus, placement } => {
                assert_eq!(placement.len(), *gpus, "event {e}");
                assert!(!held.contains_key(task), "task {task} started while held: {e}");
                for &g in placement.gpus() {
                    assert!(g < total_gpus, "GPU {g} out of range: {e}");
                    assert!(free[g], "GPU {g} double-booked: {e}");
                    free[g] = false;
                }
                held.insert(*task, placement.clone());
            }
            EventKind::Migrate { task, gpus, to, .. } => {
                // the old GPUs were already freed by the Preempt/Evict
                // that took this task off the cluster
                assert_eq!(to.len(), *gpus, "event {e}");
                assert!(!held.contains_key(task), "migrating task {task} still held: {e}");
                for &g in to.gpus() {
                    assert!(g < total_gpus, "GPU {g} out of range: {e}");
                    assert!(free[g], "GPU {g} double-booked by migration: {e}");
                    free[g] = false;
                }
                held.insert(*task, to.clone());
            }
            EventKind::Complete { task, .. } => {
                let p = held
                    .remove(task)
                    .unwrap_or_else(|| panic!("task {task} completed without holding: {e}"));
                for &g in p.gpus() {
                    assert!(!free[g], "GPU {g} freed while free: {e}");
                    free[g] = true;
                }
            }
            EventKind::Preempt { task, placement, .. } => {
                let p = held
                    .remove(task)
                    .unwrap_or_else(|| panic!("task {task} preempted without holding: {e}"));
                assert_eq!(placement, &p, "preempt released wrong GPUs: {e}");
                for &g in p.gpus() {
                    assert!(!free[g], "GPU {g} freed while free: {e}");
                    free[g] = true;
                }
            }
            EventKind::Evict { task, placement, .. } => {
                if placement.is_empty() {
                    // queue shed: the task never held GPUs
                    assert!(!held.contains_key(task), "shed task {task} still held: {e}");
                } else {
                    let p = held
                        .remove(task)
                        .unwrap_or_else(|| panic!("task {task} evicted without holding: {e}"));
                    assert_eq!(placement, &p, "evict released wrong GPUs: {e}");
                    for &g in p.gpus() {
                        assert!(!free[g], "GPU {g} freed while free: {e}");
                        free[g] = true;
                    }
                }
            }
            EventKind::Resize { task, gpus, placement, .. } => {
                if placement.is_empty() {
                    // grow past the held placement: the paired rank-grow
                    // Evict (same drain cycle) releases the old GPUs
                    assert!(held.contains_key(task), "resized a non-running task: {e}");
                } else {
                    // in place or shrink: the new placement replaces the
                    // old (a prefix of it — free-then-claim checks that)
                    assert_eq!(placement.len(), *gpus, "event {e}");
                    let old = held
                        .remove(task)
                        .unwrap_or_else(|| panic!("task {task} resized without holding: {e}"));
                    for &g in old.gpus() {
                        assert!(!free[g], "GPU {g} freed while free: {e}");
                        free[g] = true;
                    }
                    for &g in placement.gpus() {
                        assert!(g < total_gpus, "GPU {g} out of range: {e}");
                        assert!(free[g], "GPU {g} double-booked by resize: {e}");
                        free[g] = false;
                    }
                    held.insert(*task, placement.clone());
                }
            }
            EventKind::Reprice { task, .. } => {
                assert!(held.contains_key(task), "repriced a non-running task: {e}");
            }
            EventKind::Segment { .. }
            | EventKind::JobExit { .. }
            | EventKind::Fail { .. }
            | EventKind::Recover { .. }
            | EventKind::Slowdown { .. }
            | EventKind::Restore { .. } => {}
            EventKind::Adopt { .. } | EventKind::Merge { .. } => {
                // shared-executor rosters alias one placement across
                // tasks; this walker checks exclusive ownership only
                panic!("walker does not model shared-executor groups: {e}")
            }
        }
        let live: usize = held.values().map(|p| p.len()).sum();
        assert!(
            live <= total_gpus,
            "live footprint {live} exceeds the {total_gpus}-GPU capacity after {e}"
        );
    }
    assert!(held.is_empty(), "timeline ended with live allocations: {held:?}");
    assert!(free.iter().all(|&f| f), "timeline ended with a dirty bitmap");
}

#[test]
fn idle_rank_policy_changes_no_digest_bits() {
    // the no-op contract: the explicit off() policy and an enabled
    // policy whose thresholds can never fire (the sensitivity signal is
    // bounded by the penalty terms, far inside ±1e300) both replay
    // every trace family bit-identically to the default configuration —
    // planning runs, but not one digest bit moves
    let armed_idle = RankPolicy {
        grow_above: 1e300,
        shrink_below: -1e300,
        ..RankPolicy::paper()
    };
    armed_idle.validate().unwrap();
    let base = HarnessConfig {
        total_gpus: 16,
        island_size: 8,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        ..HarnessConfig::default()
    };
    for seed in [3u64, 11] {
        let cases: Vec<(&str, Trace, bool)> = vec![
            ("uniform", Trace::uniform_large(12, 32, 40.0, seed), false),
            ("frag", Trace::fragmentation_heavy(10, 32, seed), false),
            ("preempt", Trace::preemption_stress(3, 4, 32, seed), true),
            ("rank-heavy", Trace::rank_heavy(12, 2800, 30.0, seed), false),
        ];
        for (label, trace, preempt) in cases {
            let cfg = HarnessConfig {
                preempt_on_arrival: preempt,
                ..base.clone()
            };
            let clean = SimEngine::new(cfg.clone()).run_streaming(&trace).unwrap();
            for (which, policy) in [("off", RankPolicy::off()), ("armed-idle", armed_idle)] {
                let quiet = SimEngine::new(HarnessConfig {
                    rank: policy,
                    ..cfg.clone()
                })
                .run_streaming(&trace)
                .unwrap();
                let tag = format!("{label} seed {seed} ({which})");
                assert_eq!(
                    quiet.timeline.log.digest(),
                    clean.timeline.log.digest(),
                    "{tag}: idle rank machinery perturbed the digest"
                );
                assert_eq!(
                    quiet.timeline.makespan.to_bits(),
                    clean.timeline.makespan.to_bits(),
                    "{tag}: makespan drifted"
                );
                assert_eq!(
                    quiet.timeline.gpu_seconds.to_bits(),
                    clean.timeline.gpu_seconds.to_bits(),
                    "{tag}: charged GPU-seconds drifted"
                );
                assert_eq!(quiet.timeline.log.len(), clean.timeline.log.len(), "{tag}");
                assert_eq!(quiet.timeline.resizes, 0, "{tag}");
            }
        }
        // the rank-heavy family additionally through all three engine
        // paths: off() stays digest-invisible in each loop
        let trace = Trace::rank_heavy(12, 2800, 30.0, seed);
        let clean = SimEngine::new(base.clone()).run(&trace).unwrap();
        let off_cfg = HarnessConfig {
            rank: RankPolicy::off(),
            ..base.clone()
        };
        let engine = SimEngine::new(off_cfg);
        let off_batch = engine.run(&trace).unwrap();
        let off_stream = engine.run_streaming(&trace).unwrap();
        let mut src = StreamingTrace::rank_heavy(12, 2800, 30.0, seed);
        let off_src = engine.run_source(&mut src).unwrap();
        let tag = format!("rank-heavy seed {seed}");
        assert_eq!(off_batch.log.digest(), clean.log.digest(), "{tag}: batch");
        assert_eq!(
            off_stream.timeline.log.digest(),
            clean.log.digest(),
            "{tag}: streaming"
        );
        assert_eq!(off_src.log.digest(), clean.log.digest(), "{tag}: source");
    }
}

#[test]
fn rank_reallocation_replays_identically_across_all_three_engine_paths() {
    // the replay contract with the paper policy live: batch `run`,
    // streaming and the lazy source-driven loop fold Resize events and
    // rank-grow evictions into bit-identical digests, with the resize
    // counters agreeing across paths and with each other
    for seed in [3u64, 11] {
        let cfg = HarnessConfig {
            total_gpus: 16,
            island_size: 8,
            policy: Policy::Optimal,
            place: PlacePolicy::IslandFirst,
            rank: RankPolicy::paper(),
            ..HarnessConfig::default()
        };
        let trace = Trace::rank_heavy(16, 2800, 30.0, seed);
        let mut src = StreamingTrace::rank_heavy(16, 2800, 30.0, seed);
        let engine = SimEngine::new(cfg);
        let batch = engine.run(&trace).unwrap();
        let stream = engine.run_streaming(&trace).unwrap();
        let lean = engine.run_source(&mut src).unwrap();
        let tag = format!("seed {seed}");
        assert_eq!(
            stream.timeline.log.digest(),
            batch.log.digest(),
            "{tag}: streaming drifted from batch under rank reallocation"
        );
        assert_eq!(
            lean.log.digest(),
            batch.log.digest(),
            "{tag}: source-driven drifted from batch under rank reallocation"
        );
        assert_eq!(stream.timeline.log.len(), batch.log.len(), "{tag}");
        assert_eq!(lean.log.len(), batch.log.len(), "{tag}");
        assert_eq!(
            stream.timeline.makespan.to_bits(),
            batch.makespan.to_bits(),
            "{tag}: makespan drifted"
        );
        assert_eq!(lean.makespan.to_bits(), batch.makespan.to_bits(), "{tag}");
        for (path, resizes, grows, shrinks, evictions) in [
            (
                "streaming",
                stream.timeline.resizes,
                stream.timeline.rank_grows,
                stream.timeline.rank_shrinks,
                stream.timeline.resize_evictions,
            ),
            (
                "source",
                lean.resizes,
                lean.rank_grows,
                lean.rank_shrinks,
                lean.resize_evictions,
            ),
        ] {
            assert_eq!(resizes, batch.resizes, "{tag}: {path} resize count drifted");
            assert_eq!(grows, batch.rank_grows, "{tag}: {path} grow count drifted");
            assert_eq!(shrinks, batch.rank_shrinks, "{tag}: {path} shrink count drifted");
            assert_eq!(
                evictions, batch.resize_evictions,
                "{tag}: {path} eviction count drifted"
            );
        }
        // the trace is built to exercise both directions: every applied
        // step is a grow or a shrink, and every grow on this trace
        // outgrows its held placement (1 → 2 or 2 → 4 GPUs)
        assert!(batch.rank_grows >= 1, "{tag}: no grow ever fired");
        assert!(batch.rank_shrinks >= 1, "{tag}: no shrink ever fired");
        assert_eq!(batch.resizes, batch.rank_grows + batch.rank_shrinks, "{tag}");
        assert_eq!(batch.resize_evictions, batch.rank_grows, "{tag}");
        assert_eq!(lean.tasks, trace.len(), "{tag}");
    }
}

#[test]
fn rank_resizes_keep_the_rederived_bitmap_consistent_and_within_capacity() {
    // replay the event log against an independent bitmap: in-place
    // shrinks hand back their GPU suffix, grow evictions release the
    // *old* placement (their `gpus` field already reads the post-step
    // footprint), and no interleaving ever double-books a device or
    // pushes the live footprint past capacity
    let trace = Trace::rank_heavy(16, 2800, 30.0, 7);
    let report = SimEngine::new(HarnessConfig {
        total_gpus: 16,
        island_size: 8,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        rank: RankPolicy::paper(),
        ..HarnessConfig::default()
    })
    .run(&trace)
    .unwrap();
    assert!(report.rank_shrinks >= 1, "no in-place Resize to walk through");
    assert!(report.rank_grows >= 1, "no grow eviction to walk through");
    let events = report.log.events();
    // grow-shaped Resizes (empty placement) pair 1:1 with rank-grow
    // evictions; everything else resized in place
    let empty_resizes = events
        .iter()
        .filter(|e| {
            matches!(&e.kind, EventKind::Resize { placement, .. } if placement.is_empty())
        })
        .count();
    let grow_evicts = events
        .iter()
        .filter(|e| {
            matches!(
                &e.kind,
                EventKind::Evict {
                    reason: EvictReason::RankGrow,
                    ..
                }
            )
        })
        .count();
    assert_eq!(empty_resizes, grow_evicts, "unpaired grow Resize/Evict");
    assert_eq!(grow_evicts, report.resize_evictions, "counter / event-log mismatch");
    let in_place = events
        .iter()
        .filter(|e| {
            matches!(&e.kind, EventKind::Resize { placement, .. } if !placement.is_empty())
        })
        .count();
    assert_eq!(in_place + empty_resizes, report.resizes, "counter / event-log mismatch");
    walk_rank_bitmap(&report.log, 16);
}

#[test]
fn rank_grow_evictions_checkpoint_restore_and_no_task_is_lost() {
    // conservation: with faults and overload off, the only evictions a
    // rank-heavy run may contain are planned rank-grow requeues — and
    // every one of them must checkpoint-restore and complete
    let trace = Trace::rank_heavy(20, 2800, 10.0, 23);
    let report = SimEngine::new(HarnessConfig {
        total_gpus: 16,
        island_size: 8,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        rank: RankPolicy::paper(),
        ..HarnessConfig::default()
    })
    .run_streaming(&trace)
    .unwrap();
    let tl = &report.timeline;
    let (mut completes, mut evicts, mut resizes) = (0usize, 0usize, 0usize);
    for e in tl.log.events() {
        match &e.kind {
            EventKind::Complete { .. } => completes += 1,
            EventKind::Evict { reason, .. } => {
                assert_eq!(
                    *reason,
                    EvictReason::RankGrow,
                    "faults and overload are off: only rank-grow evictions may occur"
                );
                evicts += 1;
            }
            EventKind::Resize { .. } => resizes += 1,
            _ => {}
        }
    }
    assert_eq!(completes, trace.len(), "a task was lost to a rank resize");
    assert!(evicts >= 1, "growers must evict-and-requeue at least once");
    assert_eq!(evicts, tl.resize_evictions, "counter / event-log mismatch");
    assert_eq!(resizes, tl.resizes, "counter / event-log mismatch");
    assert_eq!(
        tl.resize_evictions, tl.rank_grows,
        "every grow on this trace outgrows its held placement"
    );
    assert_eq!(tl.fault_evictions, 0);
    assert_eq!(tl.sheds, 0);
    for s in &report.summaries {
        assert!(
            s.actual_duration.is_finite(),
            "task '{}' never resolved — its resize lost the checkpoint",
            s.name
        );
    }
}
