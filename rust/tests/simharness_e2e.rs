//! Integration over the `simharness` engine: deterministic replay
//! (same (trace, seed) ⇒ bit-identical event log and makespan),
//! early-exit savings on total GPU-seconds, the headline acceptance
//! scenario — a 16-GPU heterogeneous trace where the full system
//! (early exit + exact-solver replanning) strictly beats
//! FCFS-without-early-exit on simulated makespan — and the
//! streaming/batch equivalence contract: `run_streaming` (bodies
//! simulated lazily at start events, memoized across duplicates) must
//! replay bit-identical digests against the batch `run` across every
//! trace generator, pricing and preemption included.

use alto::coordinator::shared::SharingConfig;
use alto::coordinator::task_runner::RunConfig;
use alto::sched::inter::Policy;
use alto::simharness::{hetero_mix, EventKind, HarnessConfig, SimEngine, Trace};

fn engine(total_gpus: usize, policy: Policy, early_exit: bool) -> SimEngine {
    SimEngine::new(HarnessConfig {
        total_gpus,
        policy,
        run: RunConfig {
            enable_early_exit: early_exit,
            enable_warmup_selection: early_exit,
            ..RunConfig::default()
        },
        ..HarnessConfig::default()
    })
}

fn hetero_trace(n_tasks: usize, seed: u64) -> Trace {
    Trace::poisson(hetero_mix(n_tasks, 96, seed), 600.0, seed)
}

#[test]
fn replay_is_bit_identical() {
    let trace = hetero_trace(8, 42);
    // regenerating the trace from the same seed is also bit-identical
    assert_eq!(trace.fingerprint(), hetero_trace(8, 42).fingerprint());

    let a = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    let b = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    assert_eq!(a.log.digest(), b.log.digest(), "event logs must match bitwise");
    assert_eq!(a.log.events(), b.log.events());
    assert_eq!(a.log.lines(), b.log.lines());
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "makespan must match bitwise: {} vs {}",
        a.makespan,
        b.makespan
    );
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.actual_duration.to_bits(), y.actual_duration.to_bits());
        assert_eq!(x.samples_used, y.samples_used);
    }
}

#[test]
fn different_seeds_change_the_timeline() {
    let a = engine(16, Policy::Optimal, true)
        .run(&hetero_trace(8, 1))
        .unwrap();
    let b = engine(16, Policy::Optimal, true)
        .run(&hetero_trace(8, 2))
        .unwrap();
    assert_ne!(a.log.digest(), b.log.digest());
}

#[test]
fn early_exit_saves_gpu_seconds() {
    let trace = hetero_trace(8, 7);
    let with_ee = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    let without = engine(16, Policy::Optimal, false).run(&trace).unwrap();
    assert!(
        with_ee.gpu_seconds < 0.6 * without.gpu_seconds,
        "detectors on must save cluster time: {} vs {} GPU-seconds",
        with_ee.gpu_seconds,
        without.gpu_seconds
    );
    // savings come from samples not consumed, not from dropping work:
    // both runs complete every task
    let done = |r: &alto::simharness::HarnessReport| {
        r.log.count(|k| matches!(k, EventKind::Complete { .. }))
    };
    assert_eq!(done(&with_ee), trace.len());
    assert_eq!(done(&without), trace.len());
}

#[test]
fn acceptance_16_gpu_hetero_beats_fcfs_without_early_exit() {
    // the ISSUE acceptance scenario: 16 GPUs, heterogeneous tenant trace;
    // full system (early exit + exact-solver replanning) vs the naive
    // baseline (FCFS queue, no detectors)
    let trace = hetero_trace(12, 13);
    let alto = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    let baseline = engine(16, Policy::Fcfs, false).run(&trace).unwrap();
    assert!(
        alto.makespan < baseline.makespan,
        "ALTO {} must strictly beat FCFS-no-EE {}",
        alto.makespan,
        baseline.makespan
    );
    // every task completes in both configurations
    for report in [&alto, &baseline] {
        assert_eq!(
            report.log.count(|k| matches!(k, EventKind::Complete { .. })),
            trace.len()
        );
    }
}

/// Assert the streaming path replays the batch path bit for bit on one
/// (engine, trace) pair — digest, makespan bits, placements, charged
/// GPU-seconds and per-task durations.
fn assert_stream_matches_batch(engine: &SimEngine, trace: &Trace) {
    let batch = engine.run(trace).unwrap();
    let stream = engine.run_streaming(trace).unwrap();
    assert_eq!(
        stream.timeline.log.digest(),
        batch.log.digest(),
        "event logs must match bitwise"
    );
    assert_eq!(stream.timeline.makespan.to_bits(), batch.makespan.to_bits());
    assert_eq!(stream.timeline.placements, batch.placements);
    assert_eq!(
        stream.timeline.gpu_seconds.to_bits(),
        batch.gpu_seconds.to_bits()
    );
    assert_eq!(stream.timeline.reprices, batch.reprices);
    assert_eq!(stream.timeline.preemptions, batch.preemptions);
    assert_eq!(stream.timeline.migrations, batch.migrations);
    for (s, o) in stream.summaries.iter().zip(&batch.outcomes) {
        assert_eq!(s.actual_duration.to_bits(), o.actual_duration.to_bits());
        assert_eq!(s.est_duration.to_bits(), o.est_duration.to_bits());
    }
}

#[test]
fn streaming_matches_batch_on_poisson_hetero() {
    for seed in [3u64, 19] {
        let trace = hetero_trace(8, seed);
        assert_stream_matches_batch(&engine(16, Policy::Optimal, true), &trace);
    }
}

#[test]
fn streaming_matches_batch_on_fragmentation_traces() {
    for seed in [7u64, 23] {
        let trace = Trace::fragmentation_heavy(10, 48, seed);
        assert_stream_matches_batch(&engine(16, Policy::Optimal, true), &trace);
    }
}

#[test]
fn streaming_matches_batch_on_uniform_large() {
    let trace = Trace::uniform_large(24, 32, 40.0, 5);
    assert_stream_matches_batch(&engine(8, Policy::Optimal, true), &trace);
}

#[test]
fn streaming_matches_batch_under_preemption() {
    for seed in [9u64, 31] {
        let trace = Trace::preemption_stress(3, 4, 32, seed);
        let eng = SimEngine::new(HarnessConfig {
            total_gpus: 16,
            policy: Policy::Optimal,
            preempt_on_arrival: true,
            ..HarnessConfig::default()
        });
        assert_stream_matches_batch(&eng, &trace);
    }
}

#[test]
fn streaming_memoizes_duplicate_bodies() {
    // 12 arrivals cycling 4 distinct sweeps: 4 bodies simulated, 8 hits
    let trace = Trace::duplicate_heavy(12, 4, 32, 100.0, 11);
    let eng = engine(8, Policy::Optimal, true);
    let stream = eng.run_streaming(&trace).unwrap();
    assert_eq!(stream.distinct_bodies, 4);
    assert_eq!(stream.memo_hits, 8);
    // memoization must not change the timeline
    assert_stream_matches_batch(&eng, &trace);
}

#[test]
fn shared_groups_colocate_reduce_cost_and_replay_bitwise() {
    // the shared-executor acceptance scenario, e2e-sized: a co-locatable
    // stream (one family, all 1-GPU, duplicate-heavy) on a cluster small
    // enough that tenants queue — sharing on must adopt queued tasks
    // into running groups and strictly reduce both makespan and charged
    // GPU-seconds vs the same run with sharing off
    let trace = Trace::colocatable(12, 4, 32, 1.0, 17);
    let cfg_off = HarnessConfig {
        total_gpus: 2,
        policy: Policy::Optimal,
        ..HarnessConfig::default()
    };
    let cfg_on = HarnessConfig {
        sharing: SharingConfig::paper(),
        ..cfg_off.clone()
    };
    let off = SimEngine::new(cfg_off.clone()).run(&trace).unwrap();
    let on = SimEngine::new(cfg_on.clone()).run(&trace).unwrap();

    let adopts = on.log.count(|k| matches!(k, EventKind::Adopt { .. }));
    assert!(adopts > 0, "a saturated co-locatable trace must adopt");
    assert_eq!(
        off.log.count(|k| matches!(k, EventKind::Adopt { .. })),
        0,
        "sharing off must never emit Adopt events"
    );
    assert!(
        on.makespan < off.makespan,
        "sharing must shorten the timeline: {} vs {}",
        on.makespan,
        off.makespan
    );
    assert!(
        on.gpu_seconds < off.gpu_seconds,
        "sharing must cut charged GPU time: {} vs {}",
        on.gpu_seconds,
        off.gpu_seconds
    );
    // every task still completes in both configurations
    for report in [&off, &on] {
        assert_eq!(
            report.log.count(|k| matches!(k, EventKind::Complete { .. })),
            trace.len()
        );
    }
    // sharing disabled is bit-identical to the default (pre-sharing)
    // configuration — the feature is digest-invisible until enabled
    let explicit_off = SimEngine::new(HarnessConfig {
        sharing: SharingConfig::default(),
        ..cfg_off.clone()
    })
    .run(&trace)
    .unwrap();
    assert_eq!(explicit_off.log.digest(), off.log.digest());
    assert_eq!(explicit_off.makespan.to_bits(), off.makespan.to_bits());

    // the streaming twin replays the sharing timeline bit for bit,
    // Adopt/Merge events included in the digest
    assert_stream_matches_batch(&SimEngine::new(cfg_on), &trace);

    // and the sharing-bearing log round-trips through jsonl losslessly
    let back = alto::simharness::EventLog::from_jsonl(&on.log.to_jsonl()).unwrap();
    assert_eq!(back.digest(), on.log.digest());
}

#[test]
fn event_log_is_well_formed() {
    let trace = hetero_trace(8, 21);
    let report = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    let events = report.log.events();

    // timeline is totally ordered
    for w in events.windows(2) {
        assert!(w[1].time >= w[0].time, "{} then {}", w[0], w[1]);
        assert_eq!(w[1].seq, w[0].seq + 1);
    }

    // per task: exactly one arrival, one start, one completion, in order
    for task in 0..trace.len() {
        let at = |pred: &dyn Fn(&EventKind) -> bool| {
            events
                .iter()
                .find(|e| pred(&e.kind))
                .unwrap_or_else(|| panic!("missing event for task {task}"))
                .time
        };
        let arrive = at(&|k| matches!(k, EventKind::Arrival { task: t, .. } if *t == task));
        let start = at(&|k| matches!(k, EventKind::Start { task: t, .. } if *t == task));
        let complete = at(&|k| matches!(k, EventKind::Complete { task: t, .. } if *t == task));
        assert!(start >= arrive, "task {task} started before arriving");
        assert!(complete > start, "task {task} completed instantly");
        assert_eq!(
            report.log.count(|k| matches!(k, EventKind::Arrival { task: t, .. } if *t == task)),
            1
        );
    }

    // makespan equals the last completion on the clock
    let last_complete = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Complete { .. }))
        .map(|e| e.time)
        .fold(0.0, f64::max);
    assert_eq!(report.makespan.to_bits(), last_complete.to_bits());
}
