//! Integration over the `simharness` engine: deterministic replay
//! (same (trace, seed) ⇒ bit-identical event log and makespan),
//! early-exit savings on total GPU-seconds, and the headline acceptance
//! scenario — a 16-GPU heterogeneous trace where the full system
//! (early exit + exact-solver replanning) strictly beats
//! FCFS-without-early-exit on simulated makespan.

use alto::coordinator::task_runner::RunConfig;
use alto::sched::inter::Policy;
use alto::simharness::{hetero_mix, EventKind, HarnessConfig, SimEngine, Trace};

fn engine(total_gpus: usize, policy: Policy, early_exit: bool) -> SimEngine {
    SimEngine::new(HarnessConfig {
        total_gpus,
        policy,
        run: RunConfig {
            enable_early_exit: early_exit,
            enable_warmup_selection: early_exit,
            ..RunConfig::default()
        },
        ..HarnessConfig::default()
    })
}

fn hetero_trace(n_tasks: usize, seed: u64) -> Trace {
    Trace::poisson(hetero_mix(n_tasks, 96, seed), 600.0, seed)
}

#[test]
fn replay_is_bit_identical() {
    let trace = hetero_trace(8, 42);
    // regenerating the trace from the same seed is also bit-identical
    assert_eq!(trace.fingerprint(), hetero_trace(8, 42).fingerprint());

    let a = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    let b = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    assert_eq!(a.log.digest(), b.log.digest(), "event logs must match bitwise");
    assert_eq!(a.log.events(), b.log.events());
    assert_eq!(a.log.lines(), b.log.lines());
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "makespan must match bitwise: {} vs {}",
        a.makespan,
        b.makespan
    );
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.actual_duration.to_bits(), y.actual_duration.to_bits());
        assert_eq!(x.samples_used, y.samples_used);
    }
}

#[test]
fn different_seeds_change_the_timeline() {
    let a = engine(16, Policy::Optimal, true)
        .run(&hetero_trace(8, 1))
        .unwrap();
    let b = engine(16, Policy::Optimal, true)
        .run(&hetero_trace(8, 2))
        .unwrap();
    assert_ne!(a.log.digest(), b.log.digest());
}

#[test]
fn early_exit_saves_gpu_seconds() {
    let trace = hetero_trace(8, 7);
    let with_ee = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    let without = engine(16, Policy::Optimal, false).run(&trace).unwrap();
    assert!(
        with_ee.gpu_seconds < 0.6 * without.gpu_seconds,
        "detectors on must save cluster time: {} vs {} GPU-seconds",
        with_ee.gpu_seconds,
        without.gpu_seconds
    );
    // savings come from samples not consumed, not from dropping work:
    // both runs complete every task
    let done = |r: &alto::simharness::HarnessReport| {
        r.log.count(|k| matches!(k, EventKind::Complete { .. }))
    };
    assert_eq!(done(&with_ee), trace.len());
    assert_eq!(done(&without), trace.len());
}

#[test]
fn acceptance_16_gpu_hetero_beats_fcfs_without_early_exit() {
    // the ISSUE acceptance scenario: 16 GPUs, heterogeneous tenant trace;
    // full system (early exit + exact-solver replanning) vs the naive
    // baseline (FCFS queue, no detectors)
    let trace = hetero_trace(12, 13);
    let alto = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    let baseline = engine(16, Policy::Fcfs, false).run(&trace).unwrap();
    assert!(
        alto.makespan < baseline.makespan,
        "ALTO {} must strictly beat FCFS-no-EE {}",
        alto.makespan,
        baseline.makespan
    );
    // every task completes in both configurations
    for report in [&alto, &baseline] {
        assert_eq!(
            report.log.count(|k| matches!(k, EventKind::Complete { .. })),
            trace.len()
        );
    }
}

#[test]
fn event_log_is_well_formed() {
    let trace = hetero_trace(8, 21);
    let report = engine(16, Policy::Optimal, true).run(&trace).unwrap();
    let events = report.log.events();

    // timeline is totally ordered
    for w in events.windows(2) {
        assert!(w[1].time >= w[0].time, "{} then {}", w[0], w[1]);
        assert_eq!(w[1].seq, w[0].seq + 1);
    }

    // per task: exactly one arrival, one start, one completion, in order
    for task in 0..trace.len() {
        let at = |pred: &dyn Fn(&EventKind) -> bool| {
            events
                .iter()
                .find(|e| pred(&e.kind))
                .unwrap_or_else(|| panic!("missing event for task {task}"))
                .time
        };
        let arrive = at(&|k| matches!(k, EventKind::Arrival { task: t, .. } if *t == task));
        let start = at(&|k| matches!(k, EventKind::Start { task: t, .. } if *t == task));
        let complete = at(&|k| matches!(k, EventKind::Complete { task: t, .. } if *t == task));
        assert!(start >= arrive, "task {task} started before arriving");
        assert!(complete > start, "task {task} completed instantly");
        assert_eq!(
            report.log.count(|k| matches!(k, EventKind::Arrival { task: t, .. } if *t == task)),
            1
        );
    }

    // makespan equals the last completion on the clock
    let last_complete = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Complete { .. }))
        .map(|e| e.time)
        .fold(0.0, f64::max);
    assert_eq!(report.makespan.to_bits(), last_complete.to_bits());
}
