//! Integration over the `train` module: real sweeps with early exit,
//! warmup-trajectory collection, decode-based accuracy evaluation and
//! calibration — the measured halves of the Fig 1/7/10 analogs.
//! Skips when artifacts are missing.

use alto::config::HyperParams;
use alto::coordinator::task_runner::RunConfig;
use alto::data::corpus::Corpus;
use alto::runtime::{Manifest, Runtime};
use alto::stats::spearman;
use alto::train::{
    calibrate_step_time, collect_full_trajectories, gsm_accuracy, run_real_sweep,
};

const KEY: &str = "sft_nano_n4_b2_t32_r8";

fn env_or_skip() -> Option<(Runtime, Manifest)> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some((Runtime::cpu().unwrap(), Manifest::load(dir).unwrap()))
}

fn configs(lrs: &[f64]) -> Vec<HyperParams> {
    lrs.iter()
        .map(|&lr| HyperParams { lr, rank: 8, batch_size: 2 })
        .collect()
}

#[test]
fn real_sweep_separates_good_from_bad_lrs() {
    let Some((rt, m)) = env_or_skip() else { return };
    let corpus = Corpus::build("gsm-syn", 256, 16, 32, 7).unwrap();
    // mix of sane and hopeless lrs
    let cfgs = configs(&[2e-3, 5e-3, 1e-6, 1e-7]);
    let cfg = RunConfig {
        enable_early_exit: false,
        enable_warmup_selection: false,
        eval_every: 10,
        ..RunConfig::default()
    };
    let out = run_real_sweep(&rt, &m, KEY, corpus, &cfgs, 60, &cfg, 1).unwrap();
    let best = &out.result.jobs[out.result.best_job];
    assert!(
        best.hp.lr >= 1e-3,
        "a sane lr must win, got {}",
        best.hp.label()
    );
    // bad lrs barely move from init (~ln 272 ≈ 5.6)
    for j in &out.result.jobs {
        if j.hp.lr < 1e-5 {
            assert!(j.best_val > 4.5, "lr {} val {}", j.hp.lr, j.best_val);
        }
    }
}

#[test]
fn warmup_ranking_correlates_on_real_trajectories() {
    let Some((rt, m)) = env_or_skip() else { return };
    let corpus = Corpus::build("gsm-syn", 256, 16, 32, 7).unwrap();
    let cfgs = configs(&[1e-4, 5e-4, 1e-3, 2e-3]);
    let trajs =
        collect_full_trajectories(&rt, &m, KEY, corpus, &cfgs, 80, 8, 5).unwrap();
    assert_eq!(trajs.len(), 4);
    // Fig 7 analog: early (first-eval) vs final ordering correlates
    let early: Vec<f64> = trajs.iter().map(|t| t.vals[0].1).collect();
    let fin: Vec<f64> = trajs.iter().map(|t| t.best_val).collect();
    let rho = spearman(&early, &fin);
    assert!(rho > 0.0, "real warmup correlation non-positive: {rho}");
    for t in &trajs {
        assert!(t.vals.len() >= 8, "trajectory too short: {}", t.vals.len());
    }
}

#[test]
fn accuracy_eval_runs_and_is_bounded() {
    let Some((rt, m)) = env_or_skip() else { return };
    let spec = m.get(KEY).unwrap().clone();
    let corpus = Corpus::build("gsm-syn", 256, 16, spec.t, 7).unwrap();
    let cfgs = configs(&[2e-3, 2e-3, 2e-3, 2e-3]);
    let cfg = RunConfig {
        enable_early_exit: false,
        enable_warmup_selection: false,
        eval_every: 20,
        ..RunConfig::default()
    };
    let out = run_real_sweep(&rt, &m, KEY, corpus.clone(), &cfgs, 40, &cfg, 1).unwrap();
    let accs = gsm_accuracy(out.backend.session(), &corpus, 8, 6).unwrap();
    assert_eq!(accs.len(), spec.n);
    assert!(accs.iter().all(|&a| (0.0..=1.0).contains(&a)), "{accs:?}");
}

#[test]
fn calibration_produces_sane_throughput() {
    let Some((rt, m)) = env_or_skip() else { return };
    let corpus = Corpus::build("gsm-syn", 128, 8, 32, 7).unwrap();
    let cal = calibrate_step_time(&rt, &m, KEY, corpus, 5).unwrap();
    assert!(cal.step_seconds > 1e-5 && cal.step_seconds < 10.0);
    assert!(cal.effective_gflops > 0.01 && cal.effective_gflops < 1e4,
            "implausible GFLOPs {}", cal.effective_gflops);
}

#[test]
fn early_exit_on_real_backend_saves_compute_and_keeps_best() {
    let Some((rt, m)) = env_or_skip() else { return };
    let corpus = Corpus::build("gsm-syn", 256, 16, 32, 7).unwrap();
    let cfgs = configs(&[1e-4, 5e-4, 2e-3, 5e-3, 1e-2, 1e-3, 3e-3, 5e-4]);
    let full_cfg = RunConfig {
        enable_early_exit: false,
        enable_warmup_selection: false,
        eval_every: 10,
        ..RunConfig::default()
    };
    let full =
        run_real_sweep(&rt, &m, KEY, corpus.clone(), &cfgs, 50, &full_cfg, 1).unwrap();
    let ee_cfg = RunConfig { eval_every: 5, ..RunConfig::default() };
    let ee = run_real_sweep(&rt, &m, KEY, corpus, &cfgs, 50, &ee_cfg, 1).unwrap();
    assert!(
        ee.result.samples_used < full.result.samples_used / 2,
        "EE {} vs full {}",
        ee.result.samples_used,
        full.result.samples_used
    );
    // quality preserved within a band (tiny-model noise): Fig 14 analog
    let ratio = ee.result.best_val() / full.result.best_val();
    assert!(ratio < 1.35, "EE degraded best val by {ratio:.3}");
}
