//! Integration: the full L2→L3 bridge.  Loads the AOT artifacts, runs real
//! multi-adapter training steps through PJRT, and checks that losses
//! behave like training (decrease for sane lrs, stay put for inactive
//! slots, etc.).
//!
//! Requires `make artifacts` (preset `test` or wider).  Skips (with a loud
//! message) if artifacts are missing so plain `cargo test` stays green in
//! a fresh checkout.

use alto::data::corpus::{Corpus, PrefCorpus};
use alto::runtime::{Manifest, Runtime, Session};

fn manifest_or_skip() -> Option<(Runtime, Manifest)> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts/manifest.json — run `make artifacts`");
        return None;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let m = Manifest::load(&dir).expect("manifest");
    Some((rt, m))
}

const SFT_KEY: &str = "sft_nano_n4_b2_t32_r8";
const DPO_KEY: &str = "dpo_nano_n2_b2_t32_r8";

#[test]
fn sft_training_reduces_loss() {
    let Some((rt, m)) = manifest_or_skip() else { return };
    let spec = m.get(SFT_KEY).expect("test artifact").clone();
    let ranks = vec![8, 8, 4, 2];
    let lrs = vec![5e-3, 1e-3, 5e-3, 5e-3];
    let mut sess = Session::new(&rt, &m, SFT_KEY, &ranks, &lrs, 42).unwrap();
    let corpus = Corpus::build("gsm-syn", 256, 16, spec.t, 7).unwrap();

    let mut first = vec![];
    let mut last = vec![];
    for step in 0..40u64 {
        let batch = corpus.train_batch(spec.n, spec.b, step, 1);
        let losses = sess.train_step(&batch).unwrap();
        assert_eq!(losses.len(), spec.n);
        assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
        if step == 0 {
            first = losses.clone();
        }
        last = losses;
    }
    for i in 0..spec.n {
        assert!(
            last[i] < first[i],
            "adapter {i}: loss did not drop ({} -> {})",
            first[i],
            last[i]
        );
    }
    assert_eq!(sess.step_count(), 40);
}

#[test]
fn eval_is_pure_and_comparable() {
    let Some((rt, m)) = manifest_or_skip() else { return };
    let spec = m.get(SFT_KEY).unwrap().clone();
    let ranks = vec![8; 4];
    let lrs = vec![2e-3; 4];
    let mut sess = Session::new(&rt, &m, SFT_KEY, &ranks, &lrs, 1).unwrap();
    let corpus = Corpus::build("gsm-syn", 128, 8, spec.t, 3).unwrap();
    let vb = corpus.val_batch(spec.n, spec.b);
    let v1 = sess.eval(&vb).unwrap();
    let v2 = sess.eval(&vb).unwrap();
    assert_eq!(v1, v2, "eval must be deterministic / side-effect free");
    // all adapters identical at init except A's random draw: losses close
    let spread = v1.iter().cloned().fold(f64::MIN, |a, b| a.max(b as f64))
        - v1.iter().cloned().fold(f64::MAX, |a, b| a.min(b as f64));
    assert!(spread < 0.5, "fresh adapters should eval similarly: {v1:?}");
    // training changes eval
    for s in 0..10 {
        let b = corpus.train_batch(spec.n, spec.b, s, 9);
        sess.train_step(&b).unwrap();
    }
    let v3 = sess.eval(&vb).unwrap();
    assert_ne!(v1, v3);
}

#[test]
fn inactive_slot_is_frozen() {
    let Some((rt, m)) = manifest_or_skip() else { return };
    let spec = m.get(SFT_KEY).unwrap().clone();
    let mut sess =
        Session::new(&rt, &m, SFT_KEY, &[8; 4], &[5e-3; 4], 5).unwrap();
    let corpus = Corpus::build("gsm-syn", 128, 8, spec.t, 3).unwrap();
    let vb = corpus.val_batch(spec.n, spec.b);
    // deactivate slot 2, train, its val loss must not move
    sess.set_active(2, false);
    let before = sess.eval(&vb).unwrap();
    for s in 0..8 {
        let b = corpus.train_batch(spec.n, spec.b, s, 11);
        sess.train_step(&b).unwrap();
    }
    let after = sess.eval(&vb).unwrap();
    assert!(
        (before[2] - after[2]).abs() < 1e-5,
        "frozen slot moved: {} -> {}",
        before[2],
        after[2]
    );
    // active slots moved
    assert!((before[0] - after[0]).abs() > 1e-5);
}

#[test]
fn reset_slot_onloads_fresh_job() {
    let Some((rt, m)) = manifest_or_skip() else { return };
    let spec = m.get(SFT_KEY).unwrap().clone();
    let mut sess =
        Session::new(&rt, &m, SFT_KEY, &[8; 4], &[5e-3; 4], 5).unwrap();
    let corpus = Corpus::build("gsm-syn", 128, 8, spec.t, 3).unwrap();
    let vb = corpus.val_batch(spec.n, spec.b);
    for s in 0..10 {
        let b = corpus.train_batch(spec.n, spec.b, s, 13);
        sess.train_step(&b).unwrap();
    }
    let trained = sess.eval(&vb).unwrap();
    sess.reset_slot(1, 4, 1e-3, 99).unwrap();
    let reset = sess.eval(&vb).unwrap();
    // slot 1 back to (near) init loss: higher than its trained loss
    assert!(
        reset[1] > trained[1],
        "reset slot should lose training progress: {} vs {}",
        reset[1],
        trained[1]
    );
    // other slots untouched
    assert!((reset[0] - trained[0]).abs() < 1e-5);
    assert!((reset[3] - trained[3]).abs() < 1e-5);
    assert_eq!(sess.slots()[1].rank, 4);
}

#[test]
fn decode_produces_valid_tokens() {
    let Some((rt, m)) = manifest_or_skip() else { return };
    let spec = m.get(SFT_KEY).unwrap().clone();
    let sess = Session::new(&rt, &m, SFT_KEY, &[8; 4], &[2e-3; 4], 5).unwrap();
    let corpus = Corpus::build("gsm-syn", 64, 8, spec.t, 3).unwrap();
    let batch = corpus.val_batch(spec.n, spec.b);
    let pos = vec![10i32; spec.n * spec.b];
    let next = sess.decode_step(&batch.tokens, &pos).unwrap();
    assert_eq!(next.len(), spec.n * spec.b);
    assert!(next
        .iter()
        .all(|&t| (0..m.vocab as i32).contains(&t)), "{next:?}");
}

#[test]
fn dpo_training_improves_reward_accuracy() {
    let Some((rt, m)) = manifest_or_skip() else { return };
    let spec = m.get(DPO_KEY).expect("dpo artifact").clone();
    let mut sess =
        Session::new(&rt, &m, DPO_KEY, &[8, 4], &[5e-3, 2e-3], 17).unwrap();
    let pc = PrefCorpus::build(128, spec.t, 3);
    let vb = pc.val_batch(spec.n, spec.b);
    let (l0, _a0) = sess.dpo_eval(&vb).unwrap();
    let mut last_losses = vec![];
    for s in 0..30 {
        let b = pc.train_batch(spec.n, spec.b, s, 23);
        let (losses, acc) = sess.dpo_step(&b).unwrap();
        assert_eq!(losses.len(), spec.n);
        assert_eq!(acc.len(), spec.n);
        last_losses = losses;
    }
    let (l1, _a1) = sess.dpo_eval(&vb).unwrap();
    // DPO loss starts at ln 2 and must drop for at least one adapter
    assert!(l0.iter().all(|&l| (l - 0.6931).abs() < 0.05),
            "DPO loss should start at ln2: {l0:?}");
    assert!(
        l1.iter().zip(&l0).any(|(a, b)| a < b),
        "val loss should improve: {l0:?} -> {l1:?}"
    );
    assert!(last_losses.iter().all(|l| l.is_finite()));
}

#[test]
fn session_rejects_bad_shapes() {
    let Some((rt, m)) = manifest_or_skip() else { return };
    // wrong number of ranks
    assert!(Session::new(&rt, &m, SFT_KEY, &[8; 3], &[1e-3; 3], 0).is_err());
    // rank exceeding r_max
    assert!(Session::new(&rt, &m, SFT_KEY, &[16; 4], &[1e-3; 4], 0).is_err());
    // wrong batch shape
    let spec = m.get(SFT_KEY).unwrap().clone();
    let mut sess = Session::new(&rt, &m, SFT_KEY, &[8; 4], &[1e-3; 4], 0).unwrap();
    let corpus = Corpus::build("gsm-syn", 64, 8, spec.t, 3).unwrap();
    let bad = corpus.train_batch(spec.n, spec.b + 1, 0, 0);
    assert!(sess.train_step(&bad).is_err());
}
