//! Offline stub of the `xla` PJRT bindings crate.
//!
//! The real crate wraps `xla_extension` (PJRT CPU client + HLO
//! compilation); this container has no such shared library, so the repo
//! vendors an API-compatible stub: **host-side literals are fully
//! functional** (construct / reshape / read back), while everything that
//! would touch a device — client creation, HLO parsing, compilation,
//! execution — returns a descriptive error.  The PJRT integration tests
//! skip before reaching any of these calls (they check for
//! `artifacts/manifest.json` first), so `cargo test` stays green while
//! the simulated-cluster paths exercise the whole coordinator.
//!
//! Swap this path dependency for the real bindings to run AOT artifacts.

use std::any::TypeId;
use std::fmt;
use std::path::Path;

/// Stub error type (implements `std::error::Error` so it converts into
/// `anyhow::Error` at every call site).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable (offline xla stub; link the real \
         xla_extension bindings to execute artifacts)"
    )))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// A host-resident tensor literal.  Stores raw bytes plus the element
/// `TypeId`, so round-trips (`vec1` → `reshape` → `to_vec`) work exactly
/// like the real crate's host paths.
#[derive(Debug, Clone)]
pub struct Literal {
    bytes: Vec<u8>,
    elem: TypeId,
    elem_size: usize,
    dims: Vec<i64>,
}

impl Literal {
    fn from_slice<T: NativeType>(data: &[T], dims: Vec<i64>) -> Literal {
        let byte_len = std::mem::size_of_val(data);
        // SAFETY: T: Copy with no padding requirements for reading back
        // via read_unaligned; we only reinterpret the value bytes.
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, byte_len)
        }
        .to_vec();
        Literal {
            bytes,
            elem: TypeId::of::<T>(),
            elem_size: std::mem::size_of::<T>(),
            dims,
        }
    }

    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal::from_slice(&[v], vec![])
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::from_slice(data, vec![data.len() as i64])
    }

    pub fn element_count(&self) -> usize {
        if self.elem_size == 0 {
            0
        } else {
            self.bytes.len() / self.elem_size
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error(format!(
                "cannot reshape {} elements to {:?}",
                self.element_count(),
                dims
            )));
        }
        let mut out = self.clone();
        out.dims = dims.to_vec();
        Ok(out)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if TypeId::of::<T>() != self.elem {
            return Err(Error("literal element type mismatch".into()));
        }
        let n = self.element_count();
        let mut out = Vec::with_capacity(n);
        let ptr = self.bytes.as_ptr() as *const T;
        for i in 0..n {
            // SAFETY: bytes holds exactly n valid T values (written in
            // from_slice); read_unaligned tolerates the Vec<u8> alignment.
            out.push(unsafe { std::ptr::read_unaligned(ptr.add(i)) });
        }
        Ok(out)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// PJRT client handle (never constructible in the stub).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer (never constructible in the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        unavailable(&format!(
            "HloModuleProto::from_text_file({:?})",
            path.as_ref()
        ))
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, 2.5, -3.0, 0.25];
        let lit = Literal::vec1(&data).reshape(&[2, 2]).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_roundtrip_i32_scalar() {
        let lit = Literal::scalar(7i32);
        assert_eq!(lit.element_count(), 1);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn reshape_checks_element_count() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(lit.reshape(&[2, 2]).is_err());
        assert!(lit.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn device_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
    }
}
