//! Minimal, dependency-free stand-in for the `anyhow` crate (substrate:
//! crates.io is unavailable offline).  Implements exactly the API subset
//! the workspace uses: `Result`, `Error`, the `Context` extension trait
//! for `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros.  Error context is flattened into a single `": "`-joined
//! message chain rather than a source chain — enough for every
//! diagnostic surface in this repo (`{e}` / `{e:#}` / `Debug`).

use std::fmt;

/// Drop-in alias for `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Flattened error: the full context chain as one message.
pub struct Error(String);

impl Error {
    /// Construct from anything displayable (what `anyhow!` lowers to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(message.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both print the whole flattened chain
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

// Any std error converts on `?`, with its source chain flattened.
// (`Error` itself deliberately does not implement `std::error::Error`,
// exactly like real anyhow, so this blanket impl cannot overlap the
// reflexive `From<Error> for Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(cause) = src {
            msg.push_str(": ");
            msg.push_str(&cause.to_string());
            src = cause.source();
        }
        Error(msg)
    }
}

/// Context-attachment extension (the `anyhow::Context` subset).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => {
                let inner: Error = e.into();
                Err(Error(format!("{ctx}: {}", inner.0)))
            }
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => {
                let inner: Error = e.into();
                Err(Error(format!("{}: {}", f(), inner.0)))
            }
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Build an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            $crate::bail!($($t)+);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        assert_eq!(format!("{e:#}"), "boom 42");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = r.context("opening file").unwrap_err();
        assert!(e.to_string().starts_with("opening file: "), "{e}");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let v: i32 = "not a number".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            Ok(v)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(12).unwrap_err().to_string(), "v too big: 12");
    }
}
