//! Quickstart — the paper's Listing 1, end to end, in under a minute.
//!
//! Submits two heterogeneous LoRA fine-tuning tasks (different base
//! models, datasets and search spaces); ALTO plans placement with the
//! exact makespan solver, then the `simharness` event engine executes
//! the workload on a simulated 8×H100 cluster through the **streaming**
//! entry point (`SimEngine::run_streaming`): each task's search — batched
//! multi-LoRA executors + loss-aware early exit — is simulated lazily at
//! the moment the scheduler starts it, interleaved with cluster events,
//! and duplicate configurations share one memoized body.  The batch path
//! (`SimEngine::run`) replays the identical timeline bit for bit; see
//! docs/ARCHITECTURE.md for the full event flow.
//!
//!     cargo run --release --example quickstart

use alto::api::{EarlyExit, Engine};
use alto::config::{SearchSpace, TaskSpec};
use alto::simharness::{HarnessConfig, SimEngine, Trace};

fn main() -> anyhow::Result<()> {
    // 1. Initialize engine (Listing 1: strategy="adapter_parallel")
    let engine = Engine::new("adapter_parallel", 8);

    // 2. Define and batch heterogeneous tasks
    let tasks = vec![
        TaskSpec {
            name: "math-70b".into(),
            model: "llama-70b".into(),
            dataset: "gsm-syn".into(),
            num_gpus: 4,
            search_space: SearchSpace {
                lrs: vec![1e-5, 5e-5, 3e-4],
                ranks: vec![16, 64],
                batch_sizes: vec![1, 2],
            },
            train_samples: 512,
            seq_len: 512,
            ..TaskSpec::default()
        },
        TaskSpec {
            name: "chat-8b".into(),
            model: "llama-8b".into(),
            dataset: "instr-syn".into(),
            num_gpus: 1,
            search_space: SearchSpace {
                lrs: vec![5e-5, 2e-4, 5e-4],
                ranks: vec![16, 32],
                batch_sizes: vec![2, 4],
            },
            train_samples: 1024,
            seq_len: 512,
            ..TaskSpec::default()
        },
    ];

    // 3. Plan placement with the exact B&B makespan solver
    let schedule = engine.schedule(&tasks)?;
    println!("planned makespan: {:.0}s (exact B&B over {} tasks)",
             schedule.makespan, tasks.len());
    for p in &schedule.placements {
        println!("  task '{}' starts at {:.0}s on {} GPUs",
                 tasks[p.id].name, p.start, p.gpus);
    }

    // 4. Execute through the streaming event engine: one event loop,
    //    bodies simulated at start events, early exit + adapter
    //    co-location + hierarchical scheduling end to end
    let early_exit = EarlyExit::new().warmup_ratio(0.10);
    let harness = SimEngine::new(HarnessConfig {
        total_gpus: 8,
        run: early_exit.into_run_config(),
        ..HarnessConfig::default()
    });
    let report = harness.run_streaming(&Trace::at_zero(tasks))?;
    println!();
    for s in &report.summaries {
        println!(
            "task '{}': best val loss {:.4}, {:.0}% of grid-search samples \
             saved ({} of {} used), ran {:.0}s on {} GPUs",
            s.name,
            s.best_val,
            100.0 * (1.0 - s.samples_used as f64 / s.samples_budget.max(1) as f64),
            s.samples_used,
            s.samples_budget,
            s.actual_duration,
            s.gpus,
        );
    }
    println!(
        "\nrealized makespan {:.0}s · {} bodies simulated for {} tasks \
         ({} served from the memo)",
        report.timeline.makespan,
        report.distinct_bodies,
        report.summaries.len(),
        report.memo_hits,
    );
    Ok(())
}
