//! Quickstart — the paper's Listing 1, end to end, in under a minute.
//!
//! Submits two heterogeneous LoRA fine-tuning tasks (different base
//! models, datasets and search spaces) to the engine; ALTO plans
//! placement with the exact makespan solver, executes each task's search
//! with batched multi-LoRA + loss-aware early exit on the simulated
//! 8×H100 cluster, and returns the best adapter per task.
//!
//!     cargo run --release --example quickstart

use alto::api::{EarlyExit, Engine};
use alto::config::{SearchSpace, TaskSpec};

fn main() -> anyhow::Result<()> {
    // 1. Initialize engine (Listing 1: strategy="adapter_parallel")
    let engine = Engine::new("adapter_parallel", 8);

    // 2. Define and batch heterogeneous tasks
    let tasks = vec![
        TaskSpec {
            name: "math-70b".into(),
            model: "llama-70b".into(),
            dataset: "gsm-syn".into(),
            num_gpus: 4,
            search_space: SearchSpace {
                lrs: vec![1e-5, 5e-5, 3e-4],
                ranks: vec![16, 64],
                batch_sizes: vec![1, 2],
            },
            train_samples: 512,
            seq_len: 512,
            ..TaskSpec::default()
        },
        TaskSpec {
            name: "chat-8b".into(),
            model: "llama-8b".into(),
            dataset: "instr-syn".into(),
            num_gpus: 1,
            search_space: SearchSpace {
                lrs: vec![5e-5, 2e-4, 5e-4],
                ranks: vec![16, 32],
                batch_sizes: vec![2, 4],
            },
            train_samples: 1024,
            seq_len: 512,
            ..TaskSpec::default()
        },
    ];

    // 3. Set early-exit strategy, schedule and execute
    let early_exit = EarlyExit::new().warmup_ratio(0.10);
    let schedule = engine.schedule(&tasks)?;
    println!("planned makespan: {:.0}s (exact B&B over {} tasks)",
             schedule.makespan, tasks.len());
    for p in &schedule.placements {
        println!("  task '{}' starts at {:.0}s on {} GPUs",
                 tasks[p.id].name, p.start, p.gpus);
    }

    let best_adapters = engine.batched_execution(&tasks, early_exit)?;
    println!();
    for o in &best_adapters {
        println!(
            "task '{}': best val loss {:.4}, {:.0}% of grid-search samples \
             saved ({} of {} used), ran {:.0}s on {} GPUs",
            o.name,
            o.best_val,
            100.0 * (1.0 - o.samples_used as f64 / o.samples_budget as f64),
            o.samples_used,
            o.samples_budget,
            o.actual_duration,
            o.gpus,
        );
        for (reason, saved) in &o.saved_by_reason {
            println!("    saved by {reason}: {saved} samples");
        }
    }
    Ok(())
}
