//! Multi-tenant LoRA-as-a-Service — the paper's §8.2 inter-task
//! scheduling experiment shape: 11 heterogeneous tasks over four model
//! scales (70B/4-GPU, 32B/2-GPU, 8B & 7B/1-GPU) share an 8×H100
//! (simulated) cluster.  The workload is expressed as a `simharness`
//! trace, replayed through the event engine (early exit → repack →
//! replan), compared against scheduling baselines, and the realized
//! cluster timeline is printed.  The staggered-arrival section at the
//! end drives the **streaming** entry point (`SimEngine::run_streaming`,
//! docs/ARCHITECTURE.md): bodies simulate lazily at start events and
//! replay the batch path's digest bit for bit.
//!
//!     cargo run --release --example multi_task_service

use alto::config::{SearchSpace, TaskSpec};
use alto::coordinator::service::{Service, ServiceConfig};
use alto::sched::inter::{InterTaskScheduler, Policy};
use alto::simharness::{SimEngine, Trace};

fn task(name: &str, model: &str, gpus: usize, samples: usize, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.into(),
        model: model.into(),
        dataset: "gsm-syn".into(),
        num_gpus: gpus,
        search_space: SearchSpace {
            lrs: vec![5e-5, 2e-4, 5e-4],
            ranks: vec![16, 64],
            batch_sizes: vec![1, 2, 4, 8],
        },
        train_samples: samples,
        seq_len: 512,
        seed,
        ..TaskSpec::default()
    }
}

fn main() -> anyhow::Result<()> {
    // the paper's 11-task mix (§8.2 "Inter-task scheduling")
    let specs = vec![
        task("70b-a", "llama-70b", 4, 256, 1),
        task("70b-b", "llama-70b", 4, 192, 2),
        task("32b-a", "qwen-32b", 2, 256, 3),
        task("32b-b", "qwen-32b", 2, 192, 4),
        task("32b-c", "qwen-32b", 2, 160, 5),
        task("8b-a", "llama-8b", 1, 512, 6),
        task("8b-b", "llama-8b", 1, 384, 7),
        task("8b-c", "llama-8b", 1, 320, 8),
        task("7b-a", "qwen-7b", 1, 512, 9),
        task("7b-b", "qwen-7b", 1, 384, 10),
        task("7b-c", "qwen-7b", 1, 256, 11),
    ];

    let svc = Service::new(ServiceConfig::default());
    println!("running {} tasks' searches (simulated executors)...", specs.len());
    let report = svc.run_service(&specs)?;

    println!("\n{:<8} {:>5} {:>12} {:>10} {:>10} {:>9} {:>7}",
             "task", "gpus", "placed-on", "est(s)", "actual(s)", "best-val", "saved%");
    for (o, p) in report.outcomes.iter().zip(&report.placements) {
        println!(
            "{:<8} {:>5} {:>12} {:>10.0} {:>10.0} {:>9.4} {:>7.1}",
            o.name, o.gpus, p.to_string(), o.est_duration, o.actual_duration, o.best_val,
            100.0 * (1.0 - o.samples_used as f64 / o.samples_budget as f64)
        );
    }
    println!("\ncluster makespan (ALTO, exact solver + event replanning): {:.0}s",
             report.makespan);

    println!("\nrealized cluster timeline (first 12 events of {}):",
             report.events.len());
    for line in report.events.lines().iter().take(12) {
        println!("  {line}");
    }

    // scheduling-policy comparison on the same realized durations
    for policy in [Policy::Sjf, Policy::Fcfs, Policy::Lpt] {
        let mut s = InterTaskScheduler::new(8, policy);
        for (i, o) in report.outcomes.iter().enumerate() {
            s.submit(i, o.gpus, o.est_duration, o.actual_duration)?;
        }
        let mk = s.run_to_completion();
        println!("  {policy:?} makespan: {mk:.0}s ({:.2}x vs ALTO)",
                 mk / report.makespan);
    }
    println!("\ntotal samples saved across the service: {:.1}%",
             100.0 * report.total_saved_ratio());

    // the same engine streams *staggered* tenant arrivals: every task
    // lands 10 virtual minutes after the previous one, and its body is
    // simulated at the moment the scheduler starts it — not up front
    let staggered = Trace::with_arrivals(
        specs
            .iter()
            .enumerate()
            .map(|(i, s)| (600.0 * i as f64, s.clone()))
            .collect(),
    );
    let engine = SimEngine::new(ServiceConfig::default().harness());
    let r = engine.run_streaming(&staggered)?;
    println!(
        "\nstaggered arrivals (one task / 10 min, streaming bodies): \
         makespan {:.0}s, {} replans, {:.0} GPU-seconds, {} bodies \
         simulated ({} memo hits)",
        r.timeline.makespan,
        r.timeline.replans,
        r.timeline.gpu_seconds,
        r.distinct_bodies,
        r.memo_hits
    );
    // the invariant the tests pin: streaming == batch, bit for bit
    let batch = engine.run(&staggered)?;
    assert_eq!(r.timeline.log.digest(), batch.log.digest());
    println!("streaming digest == batch digest: {:016x}", batch.log.digest());
    Ok(())
}
