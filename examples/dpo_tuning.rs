//! DPO hyperparameter tuning on the *real* PJRT path (paper §8.2 "RL
//! End-to-end results"): batched multi-adapter DPO training over a shared
//! frozen backbone, loss-aware early exit, preference accuracy reported
//! per configuration.
//!
//! Requires `make artifacts` (test preset is enough).
//!
//!     cargo run --release --example dpo_tuning

use alto::data::corpus::PrefCorpus;
use alto::runtime::{Manifest, Runtime, Session};

const KEY: &str = "dpo_nano_n2_b2_t32_r8";

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load("artifacts")?;
    let spec = manifest.get(KEY)?.clone();
    println!(
        "DPO tuning on {} ({} params), {} adapters/executor, batch {}",
        spec.model.name, spec.model.param_count, spec.n, spec.b
    );

    let corpus = PrefCorpus::build(512, spec.t, 11);
    // two waves of configurations through the 2-slot executor
    let waves: [&[(usize, f64)]; 2] =
        [&[(8, 5e-3), (8, 1e-3)], &[(4, 5e-3), (2, 2e-2)]];
    let steps = 120usize;
    let mut results: Vec<(String, f64, f64)> = vec![];

    for (w, wave) in waves.iter().enumerate() {
        let ranks: Vec<usize> = wave.iter().map(|&(r, _)| r).collect();
        let lrs: Vec<f64> = wave.iter().map(|&(_, lr)| lr).collect();
        let mut sess = Session::new(&rt, &manifest, KEY, &ranks, &lrs, 40 + w as u64)?;
        let mut best_acc = vec![0.0f64; spec.n];
        for step in 0..steps as u64 {
            let b = corpus.train_batch(spec.n, spec.b, step, 5);
            let (losses, _) = sess.dpo_step(&b)?;
            if step % 20 == 19 {
                let vb = corpus.val_batch(spec.n, spec.b);
                let (vl, va) = sess.dpo_eval(&vb)?;
                for i in 0..spec.n {
                    best_acc[i] = best_acc[i].max(va[i] as f64);
                }
                println!(
                    "  wave {w} step {:>3}: train {:?} val {:?} acc {:?}",
                    step + 1,
                    losses.iter().map(|l| (l * 1e3).round() / 1e3).collect::<Vec<_>>(),
                    vl.iter().map(|l| (l * 1e3).round() / 1e3).collect::<Vec<_>>(),
                    va
                );
            }
        }
        for i in 0..spec.n {
            results.push((
                format!("r{}_lr{:.0e}", ranks[i], lrs[i]),
                best_acc[i],
                lrs[i],
            ));
        }
    }

    println!("\nconfig           best preference accuracy");
    results.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (label, acc, _) in &results {
        println!("  {label:<14} {:.1}%", 100.0 * acc);
    }
    println!(
        "\nbest configuration: {} at {:.1}% preference accuracy",
        results[0].0,
        100.0 * results[0].1
    );
    Ok(())
}
