//! End-to-end validation driver (DESIGN.md §4): trains a real
//! multi-million-parameter TinyLlama backbone with batched LoRA adapters
//! through the full stack — Pallas grouped kernels → JAX train step →
//! AOT HLO → PJRT → Rust coordinator with loss-aware early exit — on the
//! gsm-syn corpus, logging the loss curve and final strict-parse
//! accuracy.  Results are recorded in EXPERIMENTS.md.
//!
//! Picks the largest SFT artifact available (small 4.9M > micro 0.9M >
//! nano 0.1M); build more with `ARTIFACT_PRESET=default make artifacts`.
//!
//!     cargo run --release --example e2e_train -- [--steps 300]

use alto::config::HyperParams;
use alto::coordinator::executor::XlaBackend;
use alto::coordinator::task_runner::{run_task, RunConfig};
use alto::coordinator::Job;
use alto::data::corpus::Corpus;
use alto::runtime::{Manifest, Runtime};
use alto::train::accuracy::gsm_accuracy;
use alto::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(args.get_or("artifacts", "artifacts"))?;

    // largest available SFT artifact
    let key = ["sft_small_n4_b2_t64_r16", "sft_micro_n4_b2_t64_r16",
               "sft_nano_n4_b2_t32_r8"]
        .iter()
        .find(|k| manifest.artifacts.contains_key(**k))
        .copied()
        .expect("no SFT artifact — run `make artifacts`");
    let spec = manifest.get(key)?.clone();
    let steps = args.get_usize("steps", 300);
    println!(
        "e2e: {} ({:.2}M params, d={}, L={}), {} adapters × batch {} × seq {}, {steps} steps/job",
        spec.model.name,
        spec.model.param_count as f64 / 1e6,
        spec.model.d_model,
        spec.model.n_layers,
        spec.n,
        spec.b,
        spec.t
    );

    let corpus = Corpus::build("gsm-syn", 2048, 64, spec.t, 7)?;
    let eval_corpus = corpus.clone();

    // a small heterogeneous search space: 8 configs through 4 slots
    let lrs = [1e-4, 5e-4, 2e-3, 5e-3, 1e-2, 2e-3, 5e-3, 1e-3];
    let ranks = [spec.r_max, spec.r_max / 2, spec.r_max, spec.r_max / 4,
                 spec.r_max, spec.r_max, spec.r_max / 2, spec.r_max];
    let jobs: Vec<Job> = lrs
        .iter()
        .zip(ranks)
        .enumerate()
        .map(|(i, (&lr, rank))| {
            Job::new(
                i,
                HyperParams { lr, rank: rank.max(1), batch_size: spec.b },
                steps,
                90 + i as u64,
            )
        })
        .collect();

    let mut backend = XlaBackend::new_sft(&rt, &manifest, key, corpus, 3)?;
    let cfg = RunConfig {
        eval_every: (steps / 20).max(5),
        ..RunConfig::default()
    };
    let t0 = std::time::Instant::now();
    let res = run_task(&mut backend, jobs, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nloss curves (best job {}):", res.best_job);
    let best = &res.jobs[res.best_job];
    for (s, v) in &best.val_losses {
        println!("  step {:>4}: val loss {:.4}", s, v);
    }
    println!("\nper-job outcomes:");
    for j in &res.jobs {
        println!(
            "  job {} {:<18} steps {:>4} best-val {:.4} exit {}",
            j.id,
            j.hp.label(),
            j.steps_run,
            j.best_val,
            j.exit_reason().map(|r| r.as_str()).unwrap_or("-")
        );
    }
    println!(
        "\nsamples: {}/{} used ({:.0}% saved by early exit)",
        res.samples_used,
        res.samples_budget,
        100.0 * res.savings_ratio()
    );

    // strict-parse accuracy of whatever ended up in the executor slots
    let accs = gsm_accuracy(backend.session(), &eval_corpus, 32, 8)?;
    println!("slot accuracies (strict answer parsing, 32 test problems): {accs:?}");
    println!(
        "\ne2e wall-clock {:.1}s; best val loss {:.4} (init ≈ ln V = {:.2})",
        wall,
        res.best_val(),
        (spec.model.vocab as f64).ln()
    );
    anyhow::ensure!(
        res.best_val() < (spec.model.vocab as f64).ln() * 0.75,
        "training failed to reduce loss meaningfully"
    );
    println!("E2E OK");
    Ok(())
}
