"""Make `pytest python/tests/` work from the repo root: the build-time
Python package (`compile`) lives under python/."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
