//! Fig 3 — LoRA fine-tuning prefers small batch sizes: best val loss vs
//! per-adapter batch size across five learning rates (SFT), and DPO
//! reward accuracy vs batch size.  Peaks at ≤ 16, degrades beyond 32.

use alto::bench::{banner, f, pct, Table};
use alto::config::HyperParams;
use alto::data::synth::dataset_profile;
use alto::trajsim::SimJob;

const BATCHES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];
const LRS: [f64; 5] = [1e-5, 5e-5, 2e-4, 3e-4, 5e-4];

fn mean_best_val(ds: &str, lr: f64, bs: usize, seeds: u64) -> f64 {
    let prof = dataset_profile(ds).unwrap();
    let mut tot = 0.0;
    for s in 0..seeds {
        let hp = HyperParams { lr, rank: 16, batch_size: bs };
        tot += SimJob::new(&hp, prof, 400, s * 131 + 7).best_val_loss();
    }
    tot / seeds as f64
}

fn main() {
    let seeds = if alto::bench::quick() { 3 } else { 10 };
    for ds in ["gsm-syn", "instr-syn", "reason-syn"] {
        banner(&format!("Fig 3 (SFT, llama-8b analog on {ds}): val loss vs batch"));
        let mut t = Table::new(&["lr \\ batch", "1", "2", "4", "8", "16", "32", "64"]);
        for lr in LRS {
            let mut row = vec![format!("{lr:.0e}")];
            for bs in BATCHES {
                row.push(f(mean_best_val(ds, lr, bs, seeds), 3));
            }
            t.row(row);
        }
        t.print();
        // the headline check: batch 64 worse than batch ≤ 8 at the good lr
        let small = mean_best_val(ds, 2e-4, 4, seeds);
        let large = mean_best_val(ds, 2e-4, 64, seeds);
        println!(
            "at lr=2e-4: batch 4 → {:.3}, batch 64 → {:.3} ({} degradation)",
            small,
            large,
            pct(large / small - 1.0)
        );
    }

    banner("Fig 3(d) (DPO, qwen-32b analog on pref-syn): reward acc vs batch");
    let prof = dataset_profile("pref-syn").unwrap();
    let mut t = Table::new(&["lr \\ batch", "2", "4", "8", "16", "32", "64"]);
    for lr in LRS {
        let mut row = vec![format!("{lr:.0e}")];
        for bs in [2usize, 4, 8, 16, 32, 64] {
            let mut tot = 0.0;
            for s in 0..seeds {
                let hp = HyperParams { lr, rank: 32, batch_size: bs };
                tot += SimJob::new(&hp, prof, 300, s * 57 + 3).reward_accuracy();
            }
            row.push(pct(tot / seeds as f64));
        }
        t.row(row);
    }
    t.print();
    println!("(paper: performance peaks at small batch sizes ≤ 16 across all lrs)");
}
