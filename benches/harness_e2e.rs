//! Fig 9/12-style end-to-end sweep on the simharness: replay one
//! heterogeneous multi-tenant trace across GPU-count × policy × early-exit
//! configurations and report makespan, GPU-seconds and the speedup of the
//! full system (early exit + exact-solver replanning) over
//! FCFS-without-early-exit — the paper's headline composition (≤ 13.8×).
//!
//! Task bodies depend only on the early-exit switches, so they are
//! simulated once per switch setting and the (gpus × policy) grid only
//! replays timelines — the cheap half.

use alto::bench::{banner, f, Table};
use alto::cluster::PlacePolicy;
use alto::coordinator::task_runner::RunConfig;
use alto::sched::inter::Policy;
use alto::simharness::{hetero_mix, HarnessConfig, SimEngine, Trace};

fn engine(total_gpus: usize, policy: Policy, early_exit: bool) -> SimEngine {
    SimEngine::new(HarnessConfig {
        total_gpus,
        policy,
        run: RunConfig {
            enable_early_exit: early_exit,
            enable_warmup_selection: early_exit,
            ..RunConfig::default()
        },
        ..HarnessConfig::default()
    })
}

fn placement_engine(place: PlacePolicy) -> SimEngine {
    SimEngine::new(HarnessConfig {
        total_gpus: 16,
        policy: Policy::Optimal,
        place,
        ..HarnessConfig::default()
    })
}

fn main() {
    let (n_tasks, samples) = if alto::bench::quick() { (8, 64) } else { (16, 128) };
    let trace = Trace::poisson(hetero_mix(n_tasks, samples, 3), 400.0, 3);

    banner(&format!(
        "harness e2e: {} tasks (peak demand {} GPUs), poisson arrivals",
        trace.len(),
        trace.peak_gpu_demand()
    ));

    // simulate the expensive task bodies once per early-exit setting
    let bodies_off = engine(8, Policy::Fcfs, false).simulate_trace(&trace).unwrap();
    let bodies_on = engine(8, Policy::Fcfs, true).simulate_trace(&trace).unwrap();

    let mut t = Table::new(&[
        "gpus", "policy", "early-exit", "makespan(s)", "gpu-sec", "replans",
        "vs fcfs/no-ee",
    ]);
    for &gpus in &[8usize, 16, 32] {
        let baseline = engine(gpus, Policy::Fcfs, false)
            .replay(&trace, &bodies_off)
            .unwrap();
        for (policy, label) in [
            (Policy::Fcfs, "fcfs"),
            (Policy::Sjf, "sjf"),
            (Policy::Lpt, "lpt"),
            (Policy::Optimal, "optimal"),
        ] {
            for ee in [false, true] {
                let bodies = if ee { &bodies_on } else { &bodies_off };
                let r = engine(gpus, policy, ee).replay(&trace, bodies).unwrap();
                t.row(vec![
                    gpus.to_string(),
                    label.to_string(),
                    if ee { "on" } else { "off" }.to_string(),
                    f(r.makespan, 0),
                    f(r.gpu_seconds, 0),
                    r.replans.to_string(),
                    format!("{}x", f(baseline.makespan / r.makespan, 2)),
                ]);
            }
        }
    }
    t.print();
    println!(
        "\nthe bottom-right cells are the paper's composition: early exit \
         shrinks every task's occupancy, the exact solver + event-driven \
         backfill turn the freed capacity into makespan (Fig 12)."
    );

    // placement-policy sweep on a fragmentation-heavy 16-GPU trace,
    // with the perfmodel charging comm cost + co-location contention to
    // the clock (the default): the columns show what the placement
    // discipline costs in *makespan and GPU-seconds*, not just in the
    // reported comm score
    let (frag_tasks, frag_samples) = if alto::bench::quick() { (12, 32) } else { (24, 64) };
    let frag = Trace::fragmentation_heavy(frag_tasks, frag_samples, 7);
    banner(&format!(
        "placement policies: {} tasks on 16 GPUs (2 NVLink islands), fragmentation-heavy, \
         comm+contention charged",
        frag.len()
    ));
    let bodies = placement_engine(PlacePolicy::FirstFit)
        .simulate_trace(&frag)
        .unwrap();
    let mut pt = Table::new(&[
        "placement", "cross-island allocs", "comm-cost score", "makespan(s)", "gpu-sec",
        "reprices",
    ]);
    for (place, label) in [
        (PlacePolicy::FirstFit, "first-fit (blind)"),
        (PlacePolicy::IslandFirst, "island-first"),
        (PlacePolicy::BestFit, "best-fit"),
        (PlacePolicy::FragMin, "frag-min"),
    ] {
        let tl = placement_engine(place).replay(&frag, &bodies).unwrap();
        pt.row(vec![
            label.to_string(),
            tl.cross_island_allocs.to_string(),
            format!("{:.3e}", tl.placement_comm_cost),
            f(tl.makespan, 0),
            f(tl.gpu_seconds, 0),
            tl.reprices.to_string(),
        ]);
    }
    pt.print();
    println!(
        "\nisland-aware rows should never exceed the blind first-fit row: \
         with the perfmodel charging placement comm cost to the simulated \
         clock, cross-island holes cost wall time — the placement-layer \
         claim is now a makespan claim."
    );

    // large uniform trace: the first slice of harness scaling — 100+
    // 1-GPU tenants streaming through the queue; heuristic policies only
    // (the exact solver is not meant for 100-deep waiting sets)
    let (n_large, large_samples) = if alto::bench::quick() { (100, 24) } else { (200, 48) };
    let large = Trace::uniform_large(n_large, large_samples, 30.0, 5);
    banner(&format!(
        "uniform large trace: {} 1-GPU tasks (poisson), 16 GPUs",
        large.len()
    ));
    let large_engine = |policy| {
        SimEngine::new(HarnessConfig {
            total_gpus: 16,
            policy,
            ..HarnessConfig::default()
        })
    };
    let large_bodies = large_engine(Policy::Fcfs).simulate_trace(&large).unwrap();
    let mut lt = Table::new(&["policy", "makespan(s)", "gpu-sec", "replans"]);
    for (policy, label) in [
        (Policy::Fcfs, "fcfs"),
        (Policy::Sjf, "sjf"),
        (Policy::Lpt, "lpt"),
    ] {
        let tl = large_engine(policy).replay(&large, &large_bodies).unwrap();
        lt.row(vec![
            label.to_string(),
            f(tl.makespan, 0),
            f(tl.gpu_seconds, 0),
            tl.replans.to_string(),
        ]);
    }
    lt.print();
    println!(
        "\n{} tasks simulated once, replayed per policy — queue depth and \
         replan throughput are the scaling axis here, not body cost.",
        large.len()
    );
}
