//! Fig 9/12-style end-to-end sweep on the simharness: replay one
//! heterogeneous multi-tenant trace across GPU-count × policy × early-exit
//! configurations and report makespan, GPU-seconds and the speedup of the
//! full system (early exit + exact-solver replanning) over
//! FCFS-without-early-exit — the paper's headline composition (≤ 13.8×).
//!
//! Task bodies depend only on the early-exit switches, so they are
//! simulated once per switch setting and the (gpus × policy) grid only
//! replays timelines — the cheap half.

use alto::bench::{banner, f, Table};
use alto::coordinator::task_runner::RunConfig;
use alto::sched::inter::Policy;
use alto::simharness::{hetero_mix, HarnessConfig, SimEngine, Trace};

fn engine(total_gpus: usize, policy: Policy, early_exit: bool) -> SimEngine {
    SimEngine::new(HarnessConfig {
        total_gpus,
        policy,
        run: RunConfig {
            enable_early_exit: early_exit,
            enable_warmup_selection: early_exit,
            ..RunConfig::default()
        },
        ..HarnessConfig::default()
    })
}

fn main() {
    let (n_tasks, samples) = if alto::bench::quick() { (8, 64) } else { (16, 128) };
    let trace = Trace::poisson(hetero_mix(n_tasks, samples, 3), 400.0, 3);

    banner(&format!(
        "harness e2e: {} tasks (peak demand {} GPUs), poisson arrivals",
        trace.len(),
        trace.peak_gpu_demand()
    ));

    // simulate the expensive task bodies once per early-exit setting
    let bodies_off = engine(8, Policy::Fcfs, false).simulate_trace(&trace).unwrap();
    let bodies_on = engine(8, Policy::Fcfs, true).simulate_trace(&trace).unwrap();

    let mut t = Table::new(&[
        "gpus", "policy", "early-exit", "makespan(s)", "gpu-sec", "replans",
        "vs fcfs/no-ee",
    ]);
    for &gpus in &[8usize, 16, 32] {
        let baseline = engine(gpus, Policy::Fcfs, false)
            .replay(&trace, &bodies_off)
            .unwrap();
        for (policy, label) in [
            (Policy::Fcfs, "fcfs"),
            (Policy::Sjf, "sjf"),
            (Policy::Lpt, "lpt"),
            (Policy::Optimal, "optimal"),
        ] {
            for ee in [false, true] {
                let bodies = if ee { &bodies_on } else { &bodies_off };
                let r = engine(gpus, policy, ee).replay(&trace, bodies).unwrap();
                t.row(vec![
                    gpus.to_string(),
                    label.to_string(),
                    if ee { "on" } else { "off" }.to_string(),
                    f(r.makespan, 0),
                    f(r.gpu_seconds, 0),
                    r.replans.to_string(),
                    format!("{}x", f(baseline.makespan / r.makespan, 2)),
                ]);
            }
        }
    }
    t.print();
    println!(
        "\nthe bottom-right cells are the paper's composition: early exit \
         shrinks every task's occupancy, the exact solver + event-driven \
         backfill turn the freed capacity into makespan (Fig 12)."
    );
}
