//! Table 2 — kernel microbenchmark.  Two parts:
//!
//! (a) REAL (CPU PJRT): the batched N=4 grouped-kernel train step vs four
//!     sequential N=1 steps through the actual compiled artifacts — the
//!     measured analog of "Fused vs Sequential" on this host.
//! (b) ANALYTIC (H100 constants): the paper's exact setting — llama-1b
//!     scale, 32 adapters, ranks {16,32,64} mixed — Fused (ALTO grouped)
//!     vs PyTorch back-to-back (batched backbone + per-adapter LoRA
//!     kernels) vs fully Sequential, at per-adapter batch ∈ {1, 2, 4}.

use alto::bench::{banner, f, time_median, Table};
use alto::cluster::gpu::GpuSpec;
use alto::config::MODEL_FAMILY;
use alto::parallel::baselines::{Alto, MLora, Sequential};
use alto::parallel::workload::{Strategy, Workload};

fn analytic() {
    let gpu = GpuSpec::h100_sxm5();
    let model = MODEL_FAMILY.get("llama-1b").unwrap();
    banner("Table 2 (analytic, H100): 32 adapters, ranks 16/32/64 mixed, seq 256");
    let mut ranks = vec![];
    for i in 0..32 {
        ranks.push([16, 32, 64][i % 3]);
    }
    let mut t = Table::new(&[
        "per-adapter BS", "PyTorch (s)", "Sequential (s)", "Fused (s)",
        "vs PyTorch", "vs Sequential",
    ]);
    let steps = 200.0; // arbitrary fixed step count; ratios are the result
    for bs in [1usize, 2, 4] {
        let w = Workload {
            model: model.clone(),
            ranks: ranks.clone(),
            batch_per_adapter: bs,
            seq_len: 256,
        };
        let fused = Alto.step_time(&w, &gpu, 1).total() * steps;
        let pytorch = MLora.step_time(&w, &gpu, 1).total() * steps;
        let seq = Sequential.step_time(&w, &gpu, 1).total() * steps;
        t.row(vec![
            format!("{bs}"),
            f(pytorch, 1),
            f(seq, 1),
            f(fused, 1),
            format!("{:.2}x", pytorch / fused),
            format!("{:.1}x", seq / fused),
        ]);
    }
    t.print();
    println!(
        "(paper Table 2: fused 1.91x/1.74x/1.36x over PyTorch and \
         5.1x/3.7x/2.5x over Sequential at BS 1/2/4 — gains scale \
         inversely with batch size as the LoRA path's share shrinks)"
    );
}

fn real() -> anyhow::Result<()> {
    use alto::config::HyperParams;
    use alto::coordinator::executor::{Backend, XlaBackend};
    use alto::data::corpus::Corpus;
    use alto::runtime::{Manifest, Runtime};

    let rt = Runtime::cpu()?;
    let m = Manifest::load("artifacts")?;
    let (batched_key, single_key) = ("sft_nano_n4_b2_t32_r8", "sft_nano_n1_b2_t32_r8");
    if !m.artifacts.contains_key(batched_key) || !m.artifacts.contains_key(single_key) {
        println!("(real part skipped: need {batched_key} + {single_key})");
        return Ok(());
    }
    banner("Table 2 (REAL, CPU PJRT): batched N=4 grouped step vs 4 × N=1 steps");
    let corpus = Corpus::build("gsm-syn", 256, 16, 32, 7)?;
    let hp = |r: usize| HyperParams { lr: 1e-3, rank: r, batch_size: 2 };

    let mut batched = XlaBackend::new_sft(&rt, &m, batched_key, corpus.clone(), 1)?;
    for (slot, r) in [8usize, 8, 4, 2].iter().enumerate() {
        batched.onload(slot, &hp(*r), 100, slot as u64)?;
    }
    let runs = if alto::bench::quick() { 5 } else { 15 };
    let t_batched = time_median(2, runs, || {
        batched.step().unwrap();
    });

    let mut singles: Vec<XlaBackend> = [8usize, 8, 4, 2]
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut b =
                XlaBackend::new_sft(&rt, &m, single_key, corpus.clone(), 1).unwrap();
            b.onload(0, &hp(*r), 100, i as u64).unwrap();
            b
        })
        .collect();
    let t_seq = time_median(2, runs, || {
        for b in singles.iter_mut() {
            b.step().unwrap();
        }
    });

    let mut t = Table::new(&["variant", "ms/step (4 adapters)", "speedup"]);
    t.row(vec!["sequential (4 × N=1)".into(), f(t_seq * 1e3, 2), "1.00x".into()]);
    t.row(vec![
        "ALTO batched (N=4 grouped)".into(),
        f(t_batched * 1e3, 2),
        format!("{:.2}x", t_seq / t_batched),
    ]);
    t.print();
    println!(
        "(measured through the full stack: Pallas grouped kernels → HLO → \
         PJRT CPU; absolute times are CPU-bound, the *ratio* is the \
         batching effect)"
    );
    Ok(())
}

fn main() {
    analytic();
    if let Err(e) = real() {
        println!("(real part failed: {e:#})");
    }
}
