//! Fig 4 — GPU memory and SM utilization when training a single LoRA
//! adapter at small batch sizes: most of the device sits idle, motivating
//! batched multi-adapter training.  Memory from the analytic footprint
//! model; SM utilization from tile-occupancy roofline arithmetic.

use alto::bench::{banner, f, pct, Table};
use alto::cluster::gpu::GpuSpec;
use alto::cluster::memory;
use alto::config::MODEL_FAMILY;
use alto::parallel::workload::base_gemm_efficiency;

fn main() {
    let gpu = GpuSpec::h100_sxm5();
    let model = MODEL_FAMILY.get("llama-8b").unwrap();
    let seq = 1024usize;

    banner("Fig 4: single-adapter training, llama-8b analog on H100-80GB");
    let mut t = Table::new(&[
        "batch", "HBM used (GB)", "HBM util", "SM util (est)", "idle HBM (GB)",
    ]);
    for bs in [1usize, 2, 4, 8, 16, 32] {
        let mem = memory::estimate(&model, &[16], bs, seq, 1).total();
        let sm = base_gemm_efficiency(&model, (bs * seq) as f64, &gpu);
        t.row(vec![
            format!("{bs}"),
            f(mem / 1e9, 1),
            pct(mem / gpu.hbm_bytes),
            pct(sm),
            f((gpu.hbm_bytes - mem).max(0.0) / 1e9, 1),
        ]);
    }
    t.print();
    println!(
        "(paper Fig 4: a substantial portion of GPU resources remains idle \
         at small batch — the gap batched multi-adapter training reclaims)"
    );

    banner("contrast: 8 co-located adapters (ALTO batched executor)");
    let mut t = Table::new(&["per-adapter batch", "HBM used (GB)", "HBM util", "SM util (est)"]);
    for bs in [1usize, 2, 4, 8] {
        let mem = memory::estimate(&model, &[16; 8], 8 * bs, seq, 1).total();
        let sm = base_gemm_efficiency(&model, (8 * bs * seq) as f64, &gpu);
        t.row(vec![
            format!("{bs}"),
            f(mem / 1e9, 1),
            pct(mem / gpu.hbm_bytes),
            pct(sm),
        ]);
    }
    t.print();
}
