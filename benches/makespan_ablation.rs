//! Fig 12 — component ablation on 8-GPU cluster makespan with 11
//! heterogeneous tasks (2×70B/4-GPU, 3×32B/2-GPU, 6×{8B,7B}/1-GPU):
//! B = batched LoRA, S = inter-task scheduler, EE = early exit.
//! The full system (B+S+EE) vs batching alone (paper: 5.2× reduction,
//! with EE the largest single contributor).

use alto::bench::{banner, f, Table};
use alto::config::{SearchSpace, TaskSpec};
use alto::coordinator::service::{Service, ServiceConfig};
use alto::coordinator::task_runner::RunConfig;
use alto::sched::inter::Policy;

fn task(name: &str, model: &str, gpus: usize, samples: usize, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.into(),
        model: model.into(),
        dataset: "gsm-syn".into(),
        num_gpus: gpus,
        search_space: SearchSpace {
            lrs: vec![5e-5, 2e-4, 5e-4],
            ranks: vec![16, 64],
            batch_sizes: vec![1, 2, 4, 8],
        },
        train_samples: samples,
        seq_len: 512,
        seed,
        ..TaskSpec::default()
    }
}

fn main() {
    let scale = if alto::bench::quick() { 64 } else { 192 };
    // the paper's 11-task mix at varied batch sizes, in multi-tenant
    // arrival order (interleaved — tenants submit independently, so the
    // queue is not conveniently sorted; this is what FCFS actually sees)
    let specs = vec![
        task("8b-a", "llama-8b", 1, scale * 2, 6),
        task("70b-a", "llama-70b", 4, scale, 1),
        task("7b-a", "qwen-7b", 1, scale * 2, 9),
        task("32b-a", "qwen-32b", 2, scale, 3),
        task("8b-b", "llama-8b", 1, scale * 3 / 2, 7),
        task("70b-b", "llama-70b", 4, scale * 3 / 4, 2),
        task("7b-b", "qwen-7b", 1, scale * 3 / 2, 10),
        task("32b-b", "qwen-32b", 2, scale * 3 / 4, 4),
        task("8b-c", "llama-8b", 1, scale, 8),
        task("32b-c", "qwen-32b", 2, scale / 2, 5),
        task("7b-c", "qwen-7b", 1, scale, 11),
    ];

    let run_with = |ee: bool, policy: Policy| -> f64 {
        let run = if ee {
            RunConfig::default()
        } else {
            RunConfig {
                enable_early_exit: false,
                enable_warmup_selection: false,
                ..RunConfig::default()
            }
        };
        let svc = Service::new(ServiceConfig {
            policy,
            run,
            ..ServiceConfig::default()
        });
        svc.run_service(&specs).unwrap().makespan
    };

    banner("Fig 12: 8-GPU makespan by component (11 heterogeneous tasks)");
    let b = run_with(false, Policy::Fcfs);
    let bs = run_with(false, Policy::Optimal);
    let bee = run_with(true, Policy::Fcfs);
    let bsee = run_with(true, Policy::Optimal);
    let mut t = Table::new(&["configuration", "makespan (s)", "vs B"]);
    t.row(vec!["B   (batched only, FCFS)".into(), f(b, 0), "1.00x".into()]);
    t.row(vec!["B+S (batched + scheduler)".into(), f(bs, 0), format!("{:.2}x", b / bs)]);
    t.row(vec!["B+EE (batched + early exit)".into(), f(bee, 0), format!("{:.2}x", b / bee)]);
    t.row(vec!["B+S+EE (full ALTO)".into(), f(bsee, 0), format!("{:.2}x", b / bsee)]);
    t.print();
    println!(
        "\nreduction of full system vs batching alone: {:.1}x (paper: 5.2x; \
         early exit is the largest single contributor: {:.1}x alone)",
        b / bsee,
        b / bee
    );
    assert!(bee < b, "early exit must shrink makespan");
    assert!(bsee <= bee * 1.02, "scheduler must not hurt");
}
