//! Scheduling hot-path scale benchmark: wall-clock of the event-driven
//! cluster layer (arrivals → replans → priced re-pricing → completions)
//! at 100 / 1,000 / 5,000-task traces, measured for the optimized
//! scheduler AND the retained pre-optimization reference
//! (`SchedTuning::reference()`: full-fleet re-pricing + unbudgeted exact
//! replans at every queue depth).
//!
//! Persists `BENCH_sched_scale.json` (tasks/sec and events/sec per
//! scale, plus the new-vs-reference speedup at 1k) so future PRs have a
//! trajectory to beat, and **fails** (exit 1) when the committed file is
//! armed and this run's in-process 1k-task speedup ratio dropped more
//! than 2× below the committed `speedup_1k_vs_reference` — a
//! machine-independent regression gate (absolute wall-clock does not
//! compare across runners).  A fresh checkout arms the file on first
//! run; the gate goes live once a maintainer commits an armed run
//! (until then CI re-arms and uploads the numbers as an artifact only).
//!
//! The scheduler-layer workload is synthetic on purpose: this part of
//! the bench isolates the scheduling layer.  Durations are long
//! relative to arrivals (offered load > 1), so the waiting queue grows
//! into the hundreds — exactly the regime that made 100-task traces the
//! old practical ceiling.
//!
//! A second section measures the *body* layer — the other 95% of a
//! harness run, now the wall-clock floor at 1k+ tasks: eager
//! `simulate_trace` + `replay` vs the streaming `run_streaming` path
//! (bodies simulated lazily at start events, memoized across duplicate
//! specs) on a duplicate-heavy trace, recording wall time and peak
//! retained outcomes per scale into the same JSON (and asserting
//! in-process that both paths produce the bit-identical digest).
//!
//! A third section replays a co-locatable 1k-task stream (one model
//! family, all 1-GPU) with shared executor groups off and on: sharing
//! must strictly reduce both makespan and charged GPU-seconds (asserted
//! in-process — simulated outcomes are machine-independent), and the
//! ratios are persisted under `colocation` in the same JSON.
//!
//! A fourth section is the 100k-task scale point: the full streaming
//! engine on a duplicate-heavy trace, once with the stock single event
//! loop (flat completion index, every event retained) and once sharded
//! by NVLink island (`SchedTuning { shards: islands }`: sharded
//! completion index, parallel price-factor gather, parallel body
//! prefetch) with `retain_events: false` so retained state stays O(live
//! tasks).  The two digests are asserted bit-identical in-process —
//! that is the tentpole claim — and tasks/sec for both modes plus the
//! retained-event counts (the memory proxy) land under
//! `scales["100000"]`.  On a multi-core runner the sharded mode must
//! beat the single loop (ratio > 1, asserted outside quick mode), and
//! once a maintainer commits an armed run the sharded-vs-flat ratio
//! gates full runs exactly like the 1k speedup does.  A third 100k row
//! drives the same workload through `run_source` over a lazy
//! `StreamingTrace` and asserts its digest and fingerprint against the
//! materialized runs.
//!
//! Two robustness sections ride between the co-location and 100k
//! points: `faults` replays a saturated 1k-task wave under two GPU
//! failures plus an island slowdown (recovered-vs-clean makespan ratio,
//! eviction/restore counts) and `overload` drives a bursty SLO-tagged
//! 1k stream into a 16-GPU slice with admission control on (sheds,
//! deadline-miss rate).  Both assert streaming-vs-source digest
//! equality in-process — fault and shed events are replay events.
//!
//! A fifth section is the 1M-task extreme: the source-driven loop only
//! (the trace never exists as a `Vec`), digest-only retention, under a
//! 600 s wall budget — skipped in quick mode and on small runners,
//! recorded as null.  Every scale also samples `VmHWM` into
//! `peak_rss_bytes` so the trajectory records memory, not just time.
//!
//! The pre-PR `Policy::Optimal` is *not* measured beyond 100 tasks: its
//! unbudgeted exact replan is exponential on deep queues (that is the
//! problem this PR fixes), so its cell is recorded as null rather than
//! hanging the bench.

use std::time::Instant;

use alto::bench::{banner, f, Table};
use alto::cluster::gpu::GpuSpec;
use alto::cluster::{SimCluster, Topology};
use alto::config::MODEL_FAMILY;
use alto::coordinator::shared::SharingConfig;
use alto::parallel::workload::Workload;
use alto::perfmodel::StepTimeModel;
use alto::sched::inter::{
    InterTaskScheduler, OverloadConfig, Policy, Pricing, SchedTuning, Submission, TaskShape,
};
use alto::simharness::{
    uniform_mix, FaultEvent, FaultPlan, HarnessConfig, RankPolicy, SimEngine, StreamingTrace,
    TimedFault, Trace,
};
use alto::util::json::Json;
use alto::util::rng::Pcg32;

const GPUS: usize = 128;
const ISLAND: usize = 8;
const BENCH_PATH: &str = "BENCH_sched_scale.json";
/// CI fails when the armed 1k baseline regresses beyond this factor.
const GATE_FACTOR: f64 = 2.0;

/// Deterministic scheduler-level workload: 1/2/4-GPU tenants, long
/// durations on short Poisson gaps.  Offered load sits just above 1.0
/// (≈ 1.03 on 128 GPUs): the waiting queue sustains tens-deep and keeps
/// growing — deep enough that the pre-PR per-event replan dominates,
/// shallow enough that measuring the reference at 1k stays feasible (at
/// load ≫ 1 the legacy O(W³) replan would run for hours, which is the
/// regime this PR unlocks but not one a CI gate can time).
fn make_subs(n: usize, seed: u64) -> Vec<Submission> {
    let model = MODEL_FAMILY.get("llama-8b").unwrap();
    let mut rng = Pcg32::new(seed, 0x5ca1e);
    let mut at = 0.0;
    (0..n)
        .map(|i| {
            at += -6.1 * (1.0 - rng.f64()).ln();
            let gpus = *rng.choice(&[1usize, 1, 1, 1, 1, 1, 1, 2, 2, 4]);
            let d = rng.uniform(200.0, 800.0);
            Submission {
                id: i,
                gpus,
                est_duration: d,
                actual_duration: d * rng.uniform(0.5, 1.0),
                arrival: at,
                priority: 0,
                shape: Some(TaskShape {
                    workload: Workload {
                        model: model.clone(),
                        ranks: vec![16; 2],
                        batch_per_adapter: 2,
                        seq_len: 256,
                    },
                    adapters: 2,
                    rank: 16,
                }),
                ..Submission::default()
            }
        })
        .collect()
}

/// Co-locatable scheduler-level workload: every tenant a 1-GPU
/// llama-8b sweep (one family, one width — adoption-eligible into any
/// group), long durations on short Poisson gaps so the queue sustains
/// deep and shared executor groups have someone to adopt.
fn make_colo_subs(n: usize, seed: u64) -> Vec<Submission> {
    let model = MODEL_FAMILY.get("llama-8b").unwrap();
    let mut rng = Pcg32::new(seed, 0xc010);
    let mut at = 0.0;
    (0..n)
        .map(|i| {
            at += -3.8 * (1.0 - rng.f64()).ln();
            let d = rng.uniform(200.0, 800.0);
            Submission {
                id: i,
                gpus: 1,
                est_duration: d,
                actual_duration: d * rng.uniform(0.5, 1.0),
                arrival: at,
                priority: 0,
                shape: Some(TaskShape {
                    workload: Workload {
                        model: model.clone(),
                        ranks: vec![16; 2],
                        batch_per_adapter: 2,
                        seq_len: 256,
                    },
                    adapters: 2,
                    rank: 16,
                }),
                ..Submission::default()
            }
        })
        .collect()
}

struct RunStats {
    wall_s: f64,
    events: usize,
    makespan: f64,
    reprices: usize,
    deep_solves: usize,
    solver_exhausted: usize,
    charged: f64,
    adoptions: usize,
    merges: usize,
}

/// Drive the full arrival/completion event loop once and time it.
fn run_once(
    subs: &[Submission],
    policy: Policy,
    tuning: SchedTuning,
    sharing: SharingConfig,
) -> RunStats {
    let topo = Topology::uniform(GPUS, ISLAND);
    let cluster = SimCluster::with_topology(GpuSpec::h100_sxm5(), topo.clone());
    let mut s = InterTaskScheduler::with_cluster(cluster, policy);
    s.tuning = tuning;
    s.set_pricer(
        StepTimeModel::new(GpuSpec::h100_sxm5(), topo),
        Pricing::default(),
    );
    s.set_sharing(sharing);
    let t0 = Instant::now();
    let mut next = 0usize;
    let mut starts = 0usize;
    let mut reprices = 0usize;
    let mut shared_events = 0usize;
    loop {
        let arrival = subs.get(next).map(|s| s.arrival);
        let completion = s.peek_next_completion();
        let take_arrival = match (arrival, completion) {
            (None, None) => break,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some(at), Some((_, ct))) => at < ct,
        };
        if take_arrival {
            s.submit_spec(subs[next].clone())
                .expect("well-formed bench submission");
            next += 1;
        } else {
            s.complete_next()
                .expect("consistent scheduler state")
                .expect("peeked completion exists");
        }
        starts += s.drain_started().len();
        shared_events += s.drain_adopted().len() + s.drain_merged().len();
        reprices += s.drain_repriced().len();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    assert!(s.all_done(), "bench run left unfinished tasks");
    RunStats {
        wall_s,
        // arrivals + starts + completions + adopts/merges + reprices —
        // the digest-bearing event kinds a harness replay would log
        events: subs.len() * 2 + starts + shared_events + reprices,
        makespan: s.makespan(),
        reprices,
        deep_solves: s.deep_solves,
        solver_exhausted: s.solver_exhausted,
        charged: s.charged_gpu_seconds(),
        adoptions: s.adoptions,
        merges: s.merges,
    }
}

fn rate(n: usize, wall: f64) -> f64 {
    if wall > 0.0 {
        n as f64 / wall
    } else {
        f64::INFINITY
    }
}

/// Peak resident set size in bytes (VmHWM from `/proc/self/status`).
/// A process-wide high-water mark, so per-scale samples are
/// nondecreasing down the run — the signal is the jump each scale
/// adds, and above all that the 1M-task source-driven point does *not*
/// add the ~O(n) a materialized trace would.  `None` off Linux.
fn peak_rss_bytes() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024.0)
}

fn rss_json() -> Json {
    peak_rss_bytes().map(Json::Num).unwrap_or(Json::Null)
}

fn main() {
    let quick = alto::bench::quick();
    let scales: &[usize] = &[100, 1_000, 5_000];
    banner(&format!(
        "sched scale: {GPUS} GPUs ({ISLAND}-wide islands), priced clock, offered load ≈ 1.03"
    ));

    let mut table = Table::new(&[
        "tasks", "policy", "mode", "wall(s)", "tasks/s", "events/s", "reprices", "mk(s)",
    ]);
    let mut scales_json = std::collections::BTreeMap::new();
    let mut new_1k_wall = None;
    let mut ref_1k_wall = None;

    for &n in scales {
        let subs = make_subs(n, 42);
        let mut cells = std::collections::BTreeMap::new();

        let new_lpt = run_once(&subs, Policy::Lpt, SchedTuning::default(), SharingConfig::default());
        table.row(vec![
            n.to_string(),
            "lpt".into(),
            "new".into(),
            f(new_lpt.wall_s, 3),
            f(rate(n, new_lpt.wall_s), 0),
            f(rate(new_lpt.events, new_lpt.wall_s), 0),
            new_lpt.reprices.to_string(),
            f(new_lpt.makespan, 0),
        ]);
        cells.insert("new_lpt_wall_s".to_string(), Json::Num(new_lpt.wall_s));
        cells.insert(
            "new_lpt_tasks_per_s".to_string(),
            Json::Num(rate(n, new_lpt.wall_s)),
        );
        cells.insert(
            "new_lpt_events_per_s".to_string(),
            Json::Num(rate(new_lpt.events, new_lpt.wall_s)),
        );

        // the anytime Optimal path; in quick (CI smoke) mode the 5k row
        // is LPT-only to keep the workflow fast
        if !(quick && n > 1_000) {
            let new_opt =
                run_once(&subs, Policy::Optimal, SchedTuning::default(), SharingConfig::default());
            table.row(vec![
                n.to_string(),
                "optimal".into(),
                "new (anytime)".into(),
                f(new_opt.wall_s, 3),
                f(rate(n, new_opt.wall_s), 0),
                f(rate(new_opt.events, new_opt.wall_s), 0),
                new_opt.reprices.to_string(),
                f(new_opt.makespan, 0),
            ]);
            cells.insert("new_optimal_wall_s".to_string(), Json::Num(new_opt.wall_s));
            cells.insert(
                "new_optimal_deep_solves".to_string(),
                Json::Num(new_opt.deep_solves as f64),
            );
            cells.insert(
                "new_optimal_solver_exhausted".to_string(),
                Json::Num(new_opt.solver_exhausted as f64),
            );
        } else {
            cells.insert("new_optimal_wall_s".to_string(), Json::Null);
        }

        // the pre-optimization reference: full-fleet re-pricing and the
        // legacy LPT replan at every depth.  Only up to 1k tasks — at 5k
        // the O(W³)-per-event legacy plan would run for hours, which is
        // the point of this PR (recorded as null, not silently omitted).
        if n <= 1_000 {
            let reference =
                run_once(&subs, Policy::Lpt, SchedTuning::reference(), SharingConfig::default());
            let speedup = reference.wall_s / new_lpt.wall_s.max(1e-12);
            table.row(vec![
                n.to_string(),
                "lpt".into(),
                "reference (pre-PR)".into(),
                f(reference.wall_s, 3),
                f(rate(n, reference.wall_s), 0),
                f(rate(reference.events, reference.wall_s), 0),
                reference.reprices.to_string(),
                f(reference.makespan, 0),
            ]);
            cells.insert(
                "reference_lpt_wall_s".to_string(),
                Json::Num(reference.wall_s),
            );
            cells.insert("speedup_lpt".to_string(), Json::Num(speedup));
            // sanity band, not a gate: the deep plan path may order the
            // queue differently from legacy LPT, but the realized
            // makespans should stay in the same neighborhood
            if (reference.makespan - new_lpt.makespan).abs()
                > 0.25 * reference.makespan.max(1.0)
            {
                println!(
                    "warning: new ({}) and reference ({}) makespans diverged past 25%",
                    new_lpt.makespan, reference.makespan
                );
            }
            if n == 1_000 {
                new_1k_wall = Some(new_lpt.wall_s);
                ref_1k_wall = Some(reference.wall_s);
            }
        } else {
            cells.insert("reference_lpt_wall_s".to_string(), Json::Null);
            cells.insert("speedup_lpt".to_string(), Json::Null);
        }
        cells.insert("peak_rss_bytes".to_string(), rss_json());
        scales_json.insert(n.to_string(), Json::Obj(cells));
    }
    table.print();

    // ---- streaming bodies: up-front simulate_trace vs run_streaming ----
    // The other half of a harness run: task *bodies*.  A duplicate-heavy
    // tenant stream (64 distinct sweeps cycled) is replayed end to end
    // through both engine paths; the streaming path must produce the
    // bit-identical digest while simulating only the distinct bodies and
    // retaining lean summaries instead of full outcomes.
    banner("body streaming: eager simulate_trace vs run_streaming (64 distinct sweeps)");
    let mut body_table = Table::new(&[
        "tasks", "eager(s)", "stream(s)", "speedup", "bodies", "memo-hits", "retained",
    ]);
    let mut streaming_json = std::collections::BTreeMap::new();
    let body_scales: &[usize] = if quick { &[1_000] } else { &[1_000, 5_000] };
    for &n in body_scales {
        let trace = Trace::duplicate_heavy(n, 64, 48, 6.0, 42);
        let engine = SimEngine::new(HarnessConfig {
            total_gpus: GPUS,
            island_size: ISLAND,
            ..HarnessConfig::default()
        });
        let t0 = Instant::now();
        let eager = engine.run(&trace).expect("eager run");
        let eager_wall = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let stream = engine.run_streaming(&trace).expect("streaming run");
        let stream_wall = t1.elapsed().as_secs_f64();
        assert_eq!(
            stream.timeline.log.digest(),
            eager.log.digest(),
            "streaming must replay the batch digest bit for bit"
        );
        let speedup = eager_wall / stream_wall.max(1e-12);
        body_table.row(vec![
            n.to_string(),
            f(eager_wall, 3),
            f(stream_wall, 3),
            f(speedup, 1),
            stream.distinct_bodies.to_string(),
            stream.memo_hits.to_string(),
            format!("{n} vs {}", stream.distinct_bodies),
        ]);
        let mut cells = std::collections::BTreeMap::new();
        cells.insert("eager_wall_s".to_string(), Json::Num(eager_wall));
        cells.insert("streaming_wall_s".to_string(), Json::Num(stream_wall));
        cells.insert("body_speedup".to_string(), Json::Num(speedup));
        cells.insert(
            "distinct_bodies".to_string(),
            Json::Num(stream.distinct_bodies as f64),
        );
        cells.insert("memo_hits".to_string(), Json::Num(stream.memo_hits as f64));
        // peak retained outcomes: the eager path holds every task's full
        // outcome (loss histories included) before replay even starts;
        // the streaming path retains one lean memo entry per distinct
        // body plus per-task summaries
        cells.insert(
            "peak_retained_outcomes_eager".to_string(),
            Json::Num(eager.outcomes.len() as f64),
        );
        cells.insert(
            "peak_retained_bodies_streaming".to_string(),
            Json::Num(stream.distinct_bodies as f64),
        );
        cells.insert("peak_rss_bytes".to_string(), rss_json());
        streaming_json.insert(n.to_string(), Json::Obj(cells));
    }
    for &n in scales {
        if !body_scales.contains(&n) && n != 100 {
            streaming_json.insert(n.to_string(), Json::Null);
        }
    }
    body_table.print();

    // ---- shared executor groups: co-location on vs off at 1k tasks ----
    // A co-locatable stream (one family, all 1-GPU, offered load > 1)
    // replayed twice through the scheduler layer: sharing off, then
    // sharing on.  Both timelines are deterministic simulated outcomes,
    // so the win is asserted in-process (machine-independent) and the
    // ratios are persisted for the trajectory.
    banner("shared executor groups: 1k-task co-locatable stream, sharing on vs off");
    let colo_subs = make_colo_subs(1_000, 42);
    let colo_off = run_once(
        &colo_subs,
        Policy::Optimal,
        SchedTuning::default(),
        SharingConfig::default(),
    );
    let colo_on = run_once(
        &colo_subs,
        Policy::Optimal,
        SchedTuning::default(),
        SharingConfig::paper(),
    );
    let mut colo_table = Table::new(&[
        "sharing", "wall(s)", "mk(s)", "gpu-s", "adoptions", "merges",
    ]);
    colo_table.row(vec![
        "off".into(),
        f(colo_off.wall_s, 3),
        f(colo_off.makespan, 0),
        f(colo_off.charged, 0),
        colo_off.adoptions.to_string(),
        colo_off.merges.to_string(),
    ]);
    colo_table.row(vec![
        "on".into(),
        f(colo_on.wall_s, 3),
        f(colo_on.makespan, 0),
        f(colo_on.charged, 0),
        colo_on.adoptions.to_string(),
        colo_on.merges.to_string(),
    ]);
    colo_table.print();
    assert_eq!(colo_off.adoptions, 0, "sharing off must never adopt");
    assert!(
        colo_on.adoptions > 0,
        "a saturated co-locatable 1k stream must adopt"
    );
    assert!(
        colo_on.makespan < colo_off.makespan,
        "sharing must strictly shorten the makespan: {} vs {}",
        colo_on.makespan,
        colo_off.makespan
    );
    assert!(
        colo_on.charged < colo_off.charged,
        "sharing must strictly cut charged GPU-seconds: {} vs {}",
        colo_on.charged,
        colo_off.charged
    );
    let colo_json = Json::obj(vec![
        ("tasks", Json::Num(1_000.0)),
        ("makespan_off_s", Json::Num(colo_off.makespan)),
        ("makespan_on_s", Json::Num(colo_on.makespan)),
        (
            "makespan_ratio",
            Json::Num(colo_on.makespan / colo_off.makespan.max(1e-12)),
        ),
        ("gpu_seconds_off", Json::Num(colo_off.charged)),
        ("gpu_seconds_on", Json::Num(colo_on.charged)),
        (
            "gpu_seconds_ratio",
            Json::Num(colo_on.charged / colo_off.charged.max(1e-12)),
        ),
        ("adoptions", Json::Num(colo_on.adoptions as f64)),
        ("merges", Json::Num(colo_on.merges as f64)),
    ]);
    println!(
        "co-location: makespan {} → {} ({:.2}×), GPU-s {} → {} ({:.2}×), {} adoptions / {} merges",
        f(colo_off.makespan, 0),
        f(colo_on.makespan, 0),
        colo_on.makespan / colo_off.makespan.max(1e-12),
        f(colo_off.charged, 0),
        f(colo_on.charged, 0),
        colo_on.charged / colo_off.charged.max(1e-12),
        colo_on.adoptions,
        colo_on.merges,
    );

    // ---- fault injection: recovery cost at 1k tasks -------------------
    // A dense t = 0 wave of 1k single-GPU tenants saturates all 128
    // GPUs, so the early GPU failures are guaranteed to evict live
    // runners; the plan also derates one island mid-run.  The same
    // faulted replay is driven through the streaming and the lazy
    // source-driven loop and the digests asserted bit-identical — the
    // fault timeline is part of the replay contract, not a side effect.
    banner("fault injection: 1k-task wave, 2 GPU failures + 1 island slowdown");
    let fault_trace = Trace::at_zero(uniform_mix(1_000, 48, 42));
    let clean_cfg = HarnessConfig {
        total_gpus: GPUS,
        island_size: ISLAND,
        retain_events: false,
        ..HarnessConfig::default()
    };
    let clean_run = SimEngine::new(clean_cfg.clone())
        .run_streaming(&fault_trace)
        .expect("clean 1k run");
    let fault_plan = FaultPlan::new(vec![
        TimedFault {
            time: 1.0,
            event: FaultEvent::GpuFail { gpu: 7 },
        },
        TimedFault {
            time: 2.0,
            event: FaultEvent::GpuFail { gpu: 63 },
        },
        TimedFault {
            time: 5.0,
            event: FaultEvent::IslandSlowdown {
                island: 3,
                factor: 1.6,
            },
        },
        TimedFault {
            time: 400.0,
            event: FaultEvent::IslandRestore { island: 3 },
        },
        TimedFault {
            time: 600.0,
            event: FaultEvent::GpuRecover { gpu: 7 },
        },
        TimedFault {
            time: 700.0,
            event: FaultEvent::GpuRecover { gpu: 63 },
        },
    ])
    .with_checkpoint_interval(120.0);
    let faulted_cfg = HarnessConfig {
        faults: fault_plan,
        ..clean_cfg.clone()
    };
    let faulted_engine = SimEngine::new(faulted_cfg);
    let faulted = faulted_engine
        .run_streaming(&fault_trace)
        .expect("faulted 1k run");
    let faulted_src = faulted_engine
        .run_source(&mut fault_trace.source())
        .expect("faulted source-driven run");
    assert_eq!(
        faulted_src.log.digest(),
        faulted.timeline.log.digest(),
        "faulted source-driven replay drifted from the streaming digest"
    );
    assert_eq!(
        faulted_src.fault_evictions,
        faulted.timeline.fault_evictions
    );
    assert!(
        faulted.timeline.fault_evictions >= 2,
        "both failed GPUs held runners on a saturated wave \
         ({} evictions)",
        faulted.timeline.fault_evictions
    );
    assert_eq!(faulted.timeline.sheds, 0, "overload is off in this section");
    let recovered_ratio = faulted.timeline.makespan / clean_run.timeline.makespan.max(1e-12);
    println!(
        "clean makespan {}s vs recovered {}s ({recovered_ratio:.3}×), \
         {} evictions checkpoint-restored",
        f(clean_run.timeline.makespan, 0),
        f(faulted.timeline.makespan, 0),
        faulted.timeline.fault_evictions,
    );
    let faults_json = Json::obj(vec![
        ("tasks", Json::Num(1_000.0)),
        ("gpu_failures", Json::Num(2.0)),
        ("island_slowdowns", Json::Num(1.0)),
        ("checkpoint_interval_s", Json::Num(120.0)),
        ("clean_makespan_s", Json::Num(clean_run.timeline.makespan)),
        ("recovered_makespan_s", Json::Num(faulted.timeline.makespan)),
        ("recovered_vs_clean_makespan", Json::Num(recovered_ratio)),
        (
            "fault_evictions",
            Json::Num(faulted.timeline.fault_evictions as f64),
        ),
        (
            "restores",
            Json::Num(faulted.timeline.fault_evictions as f64),
        ),
    ]);

    // ---- overload control: admission under pressure at 1k tasks -------
    // Bursty arrivals (32-task waves) pounding a deliberately small
    // 16-GPU slice, every task carrying an SLO deadline and one of four
    // tenants (one double-weighted): the shed pass fires when the
    // waiting queue tops the pressure threshold.  Streaming and
    // source-driven replays must agree bit for bit — sheds are digest
    // events like any other.
    banner("overload control: 1k-task bursty stream on 16 GPUs, weighted admission + SLOs");
    let mut over_trace = Trace::bursty_uniform(1_000, 48, 32, 600.0, 42);
    for (i, e) in over_trace.entries.iter_mut().enumerate() {
        e.spec.tenant = format!("tenant-{}", i % 4);
        e.spec.tenant_weight = if i % 4 == 0 { 2.0 } else { 1.0 };
        e.spec.slo_deadline = 2_400.0;
    }
    let over_engine = SimEngine::new(HarnessConfig {
        total_gpus: 16,
        island_size: ISLAND,
        retain_events: false,
        overload: OverloadConfig {
            enabled: true,
            pressure_threshold: 48,
        },
        ..HarnessConfig::default()
    });
    let over = over_engine
        .run_streaming(&over_trace)
        .expect("overloaded 1k run");
    let over_src = over_engine
        .run_source(&mut over_trace.source())
        .expect("overloaded source-driven run");
    assert_eq!(
        over_src.log.digest(),
        over.timeline.log.digest(),
        "overloaded source-driven replay drifted from the streaming digest"
    );
    assert_eq!(over_src.sheds, over.timeline.sheds);
    assert_eq!(over_src.deadline_misses, over.timeline.deadline_misses);
    let miss_rate = over.timeline.deadline_misses as f64 / 1_000.0;
    println!(
        "{} shed under pressure, {} deadline misses ({:.1}% of 1k tasks)",
        over.timeline.sheds,
        over.timeline.deadline_misses,
        miss_rate * 100.0,
    );
    let overload_json = Json::obj(vec![
        ("tasks", Json::Num(1_000.0)),
        ("gpus", Json::Num(16.0)),
        ("pressure_threshold", Json::Num(48.0)),
        ("slo_deadline_s", Json::Num(2_400.0)),
        ("sheds", Json::Num(over.timeline.sheds as f64)),
        (
            "deadline_misses",
            Json::Num(over.timeline.deadline_misses as f64),
        ),
        ("deadline_miss_rate", Json::Num(miss_rate)),
        ("makespan_s", Json::Num(over.timeline.makespan)),
    ]);

    // ---- dynamic rank reallocation: adaptive vs fixed rank ------------
    // The rank-heavy mix (three plateau-bound max-rank tenants for every
    // undersized rank-2 tenant) with the policy off and with the paper
    // thresholds.  The bodies resolve at admission-frozen HPs, so only
    // the cluster books move: shrinks hand back a GPU per plateaued
    // tenant mid-flight, grows evict-and-requeue at a wider footprint,
    // and charged GPU-seconds must strictly drop — asserted in-process.
    let rank_n = if quick { 64 } else { 200 };
    banner(&format!(
        "dynamic rank reallocation: {rank_n}-task rank-heavy stream, adaptive vs fixed"
    ));
    let rank_trace = Trace::rank_heavy(rank_n, 2_800, 4.0, 42);
    let rank_base = HarnessConfig {
        total_gpus: GPUS,
        island_size: ISLAND,
        retain_events: false,
        ..HarnessConfig::default()
    };
    let rank_fixed = SimEngine::new(rank_base.clone())
        .run_streaming(&rank_trace)
        .expect("fixed-rank run");
    let rank_adapt = SimEngine::new(HarnessConfig {
        rank: RankPolicy::paper(),
        ..rank_base
    })
    .run_streaming(&rank_trace)
    .expect("adaptive-rank run");
    assert_eq!(rank_fixed.timeline.resizes, 0, "the default policy must stay off");
    assert!(
        rank_adapt.timeline.rank_shrinks > 0 && rank_adapt.timeline.rank_grows > 0,
        "the rank-heavy trace must exercise both directions \
         ({} grows / {} shrinks)",
        rank_adapt.timeline.rank_grows,
        rank_adapt.timeline.rank_shrinks
    );
    assert!(
        rank_adapt.timeline.gpu_seconds < rank_fixed.timeline.gpu_seconds,
        "adaptive rank must strictly cut charged GPU-seconds: {} vs {}",
        rank_adapt.timeline.gpu_seconds,
        rank_fixed.timeline.gpu_seconds
    );
    let rank_mk_ratio =
        rank_adapt.timeline.makespan / rank_fixed.timeline.makespan.max(1e-12);
    let rank_gpu_ratio =
        rank_adapt.timeline.gpu_seconds / rank_fixed.timeline.gpu_seconds.max(1e-12);
    println!(
        "rank: makespan {} → {} ({rank_mk_ratio:.3}×), GPU-s {} → {} \
         ({rank_gpu_ratio:.3}×), {} resizes ({} grows / {} shrinks, \
         {} grow evictions)",
        f(rank_fixed.timeline.makespan, 0),
        f(rank_adapt.timeline.makespan, 0),
        f(rank_fixed.timeline.gpu_seconds, 0),
        f(rank_adapt.timeline.gpu_seconds, 0),
        rank_adapt.timeline.resizes,
        rank_adapt.timeline.rank_grows,
        rank_adapt.timeline.rank_shrinks,
        rank_adapt.timeline.resize_evictions,
    );
    let rank_json = Json::obj(vec![
        ("tasks", Json::Num(rank_n as f64)),
        ("resizes", Json::Num(rank_adapt.timeline.resizes as f64)),
        ("rank_grows", Json::Num(rank_adapt.timeline.rank_grows as f64)),
        (
            "rank_shrinks",
            Json::Num(rank_adapt.timeline.rank_shrinks as f64),
        ),
        (
            "resize_evictions",
            Json::Num(rank_adapt.timeline.resize_evictions as f64),
        ),
        ("makespan_fixed_s", Json::Num(rank_fixed.timeline.makespan)),
        ("makespan_adaptive_s", Json::Num(rank_adapt.timeline.makespan)),
        ("makespan_ratio", Json::Num(rank_mk_ratio)),
        ("gpu_seconds_fixed", Json::Num(rank_fixed.timeline.gpu_seconds)),
        (
            "gpu_seconds_adaptive",
            Json::Num(rank_adapt.timeline.gpu_seconds),
        ),
        ("gpu_seconds_ratio", Json::Num(rank_gpu_ratio)),
    ]);

    // ---- sharded event loop: the 100k-task scale point ----------------
    // The tentpole measurement: a duplicate-heavy 100k-tenant stream
    // through the whole streaming engine, single loop vs sharded by
    // NVLink island.  Digest equality is the correctness claim and is
    // asserted in-process; the persisted numbers are the throughput
    // trajectory (absolute wall-clock is machine-local, the ratio is
    // not).  Quick mode drops to 10k tasks so the CI smoke stays fast.
    let n_islands = GPUS / ISLAND;
    let big_n: usize = if quick { 10_000 } else { 100_000 };
    banner(&format!(
        "sharded event loop: {big_n}-task duplicate-heavy stream, shards={n_islands} vs single loop"
    ));
    let big_trace = Trace::duplicate_heavy(big_n, 2_048, 48, 6.0, 42);
    let flat_cfg = HarnessConfig {
        total_gpus: GPUS,
        island_size: ISLAND,
        ..HarnessConfig::default()
    };
    let t_flat = Instant::now();
    let flat = SimEngine::new(flat_cfg.clone())
        .run_streaming(&big_trace)
        .expect("single-loop 100k run");
    let flat_wall = t_flat.elapsed().as_secs_f64();
    let shard_cfg = HarnessConfig {
        tuning: SchedTuning {
            shards: n_islands,
            ..SchedTuning::default()
        },
        retain_events: false,
        ..flat_cfg
    };
    let t_shard = Instant::now();
    let shard = SimEngine::new(shard_cfg.clone())
        .run_streaming(&big_trace)
        .expect("sharded 100k run");
    let shard_wall = t_shard.elapsed().as_secs_f64();
    assert_eq!(
        shard.timeline.log.digest(),
        flat.timeline.log.digest(),
        "sharded {big_n}-task replay drifted from the single-loop digest"
    );
    // the lazy-source loop must land on the very same digest without
    // the trace ever existing as a Vec — the at-scale half of the
    // `run_source` contract (the property suite pins it per generator
    // at small n)
    let mut big_src = StreamingTrace::duplicate_heavy(big_n, 2_048, 48, 6.0, 42);
    let t_src = Instant::now();
    let src = SimEngine::new(shard_cfg.clone())
        .run_source(&mut big_src)
        .expect("source-driven run");
    let src_wall = t_src.elapsed().as_secs_f64();
    assert_eq!(
        src.log.digest(),
        flat.timeline.log.digest(),
        "source-driven {big_n}-task replay drifted from the materialized digest"
    );
    assert_eq!(
        src.fingerprint,
        big_trace.fingerprint(),
        "the lazy source drifted from the materialized trace"
    );
    assert_eq!(src.makespan.to_bits(), flat.timeline.makespan.to_bits());
    assert_eq!(src.tasks, big_n);
    assert_eq!(
        shard.timeline.makespan.to_bits(),
        flat.timeline.makespan.to_bits()
    );
    assert_eq!(shard.timeline.log.len(), flat.timeline.log.len());
    assert_eq!(
        shard.timeline.log.retained(),
        0,
        "digest-only mode must retain no event records"
    );
    let shard_ratio = flat_wall / shard_wall.max(1e-12);
    let mut big_table = Table::new(&[
        "mode", "wall(s)", "tasks/s", "events", "retained", "bodies", "memo-hits",
    ]);
    big_table.row(vec![
        "single loop".into(),
        f(flat_wall, 1),
        f(rate(big_n, flat_wall), 0),
        flat.timeline.log.len().to_string(),
        flat.timeline.log.retained().to_string(),
        flat.distinct_bodies.to_string(),
        flat.memo_hits.to_string(),
    ]);
    big_table.row(vec![
        format!("sharded ×{n_islands}"),
        f(shard_wall, 1),
        f(rate(big_n, shard_wall), 0),
        shard.timeline.log.len().to_string(),
        shard.timeline.log.retained().to_string(),
        shard.distinct_bodies.to_string(),
        shard.memo_hits.to_string(),
    ]);
    big_table.row(vec![
        "source-driven".into(),
        f(src_wall, 1),
        f(rate(big_n, src_wall), 0),
        src.log.len().to_string(),
        src.log.retained().to_string(),
        src.distinct_bodies.to_string(),
        src.memo_hits.to_string(),
    ]);
    big_table.print();
    println!(
        "sharded speedup at {big_n} tasks: {shard_ratio:.2}× \
         (retained events {} → 0)",
        flat.timeline.log.retained()
    );
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 4 && !quick {
        assert!(
            shard_ratio > 1.0,
            "sharded mode must beat the single loop on a {cores}-core runner \
             ({flat_wall:.1}s vs {shard_wall:.1}s)"
        );
        assert!(
            flat_wall < 600.0 && shard_wall < 600.0,
            "100k-task run blew the 600 s wall budget \
             (flat {flat_wall:.1}s, sharded {shard_wall:.1}s)"
        );
    }
    let mut big_cells = std::collections::BTreeMap::new();
    big_cells.insert("flat_wall_s".to_string(), Json::Num(flat_wall));
    big_cells.insert("sharded_wall_s".to_string(), Json::Num(shard_wall));
    big_cells.insert(
        "flat_tasks_per_s".to_string(),
        Json::Num(rate(big_n, flat_wall)),
    );
    big_cells.insert(
        "sharded_tasks_per_s".to_string(),
        Json::Num(rate(big_n, shard_wall)),
    );
    big_cells.insert("sharded_speedup".to_string(), Json::Num(shard_ratio));
    big_cells.insert("shards".to_string(), Json::Num(n_islands as f64));
    big_cells.insert(
        "retained_events_flat".to_string(),
        Json::Num(flat.timeline.log.retained() as f64),
    );
    big_cells.insert(
        "retained_events_sharded".to_string(),
        Json::Num(shard.timeline.log.retained() as f64),
    );
    big_cells.insert(
        "distinct_bodies".to_string(),
        Json::Num(shard.distinct_bodies as f64),
    );
    big_cells.insert("source_wall_s".to_string(), Json::Num(src_wall));
    big_cells.insert(
        "source_tasks_per_s".to_string(),
        Json::Num(rate(big_n, src_wall)),
    );
    big_cells.insert("peak_rss_bytes".to_string(), rss_json());
    scales_json.insert(big_n.to_string(), Json::Obj(big_cells));

    // ---- the 1M-task extreme: source-driven, digest-only --------------
    // The trace never exists: a lazy StreamingTrace feeds `run_source`
    // (slab retirement + digest-only retention), so peak memory is
    // O(live tasks + distinct bodies) while a million tenants stream
    // through.  Mean interarrival 8.0 keeps offered load below 1 so the
    // live window stays bounded — the regime the 1M mode exists for (a
    // load-above-1 backlog grows with trace length and would hold O(n)
    // waiting specs no matter how lazily they arrive).  Skipped in
    // quick mode and on small runners, recorded as null rather than
    // silently omitted.
    let mut m_cells = std::collections::BTreeMap::new();
    if !quick && cores >= 4 {
        const M: usize = 1_000_000;
        banner(&format!(
            "1M-task source-driven stream: shards={n_islands}, digest-only"
        ));
        let mut m_src = StreamingTrace::duplicate_heavy(M, 2_048, 48, 8.0, 42);
        let t_m = Instant::now();
        let m = SimEngine::new(shard_cfg.clone())
            .run_source(&mut m_src)
            .expect("1M-task source run");
        let m_wall = t_m.elapsed().as_secs_f64();
        assert_eq!(m.tasks, M, "the source must deliver every entry");
        assert_eq!(
            m.log.retained(),
            0,
            "the 1M point must run digest-only"
        );
        assert!(
            m_wall < 600.0,
            "1M-task source run blew the 600 s wall budget ({m_wall:.1}s)"
        );
        println!(
            "1M tasks in {m_wall:.1}s ({} tasks/s, {} events, \
             digest {:016x}, fingerprint {:016x})",
            f(rate(M, m_wall), 0),
            m.log.len(),
            m.log.digest(),
            m.fingerprint,
        );
        m_cells.insert("source_wall_s".to_string(), Json::Num(m_wall));
        m_cells.insert(
            "source_tasks_per_s".to_string(),
            Json::Num(rate(M, m_wall)),
        );
        m_cells.insert("events".to_string(), Json::Num(m.log.len() as f64));
        m_cells.insert("makespan_s".to_string(), Json::Num(m.makespan));
        m_cells.insert(
            "distinct_bodies".to_string(),
            Json::Num(m.distinct_bodies as f64),
        );
        m_cells.insert(
            "digest".to_string(),
            Json::Str(format!("{:016x}", m.log.digest())),
        );
        m_cells.insert(
            "fingerprint".to_string(),
            Json::Str(format!("{:016x}", m.fingerprint)),
        );
        m_cells.insert("peak_rss_bytes".to_string(), rss_json());
    } else {
        m_cells.insert("source_wall_s".to_string(), Json::Null);
    }
    scales_json.insert("1000000".to_string(), Json::Obj(m_cells));

    let speedup_1k = match (new_1k_wall, ref_1k_wall) {
        (Some(new), Some(reference)) => reference / new.max(1e-12),
        _ => f64::NAN,
    };
    println!(
        "\n1k-task trace: reference {}s vs new {}s → {:.1}× (acceptance bar: ≥ 10×)",
        f(ref_1k_wall.unwrap_or(f64::NAN), 3),
        f(new_1k_wall.unwrap_or(f64::NAN), 3),
        speedup_1k
    );

    // ---- regression gate + arming -------------------------------------
    // Absolute wall-clock does not compare across machines, and a
    // same-job rerun of the identical binary can only measure noise.
    // The gate is therefore the in-process *ratio*: new-vs-reference
    // speedup at 1k tasks, measured in this very run, against the
    // committed armed baseline's ratio.  A real hot-path regression
    // slows the new path but not the reference, collapsing the ratio on
    // any machine; runner speed cancels out.
    let prior = std::fs::read_to_string(BENCH_PATH)
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let mut gate_failed = false;
    if let Some(prior) = &prior {
        let armed = prior.get("armed").and_then(|j| j.as_bool()).unwrap_or(false);
        let baseline = prior
            .get("speedup_1k_vs_reference")
            .and_then(|j| j.as_f64())
            .filter(|v| v.is_finite() && *v > 0.0);
        match (armed, baseline) {
            (true, Some(baseline)) if speedup_1k.is_finite() => {
                if speedup_1k < baseline / GATE_FACTOR {
                    eprintln!(
                        "REGRESSION: 1k-task new-vs-reference speedup fell to \
                         {speedup_1k:.1}× vs the armed baseline {baseline:.1}× \
                         (more than {GATE_FACTOR}× worse)"
                    );
                    gate_failed = true;
                } else {
                    println!(
                        "gate: 1k speedup {speedup_1k:.1}× within {GATE_FACTOR}× of the \
                         armed baseline {baseline:.1}×"
                    );
                }
            }
            _ => println!("gate: no armed speedup baseline — arming this run's numbers"),
        }
        // the 100k sharded-vs-flat ratio gates the same way once armed:
        // a sharding regression collapses this run's in-process ratio on
        // any machine, while runner speed cancels out.  Quick runs
        // measure 10k tasks, so only full runs consult the gate.
        if !quick {
            let shard_baseline = prior
                .get("scales")
                .and_then(|s| s.get("100000"))
                .and_then(|s| s.get("sharded_speedup"))
                .and_then(|j| j.as_f64())
                .filter(|v| v.is_finite() && *v > 0.0);
            match (armed, shard_baseline) {
                (true, Some(baseline)) if shard_ratio.is_finite() => {
                    if shard_ratio < baseline / GATE_FACTOR {
                        eprintln!(
                            "REGRESSION: 100k sharded-vs-flat speedup fell to \
                             {shard_ratio:.2}× vs the armed baseline {baseline:.2}× \
                             (more than {GATE_FACTOR}× worse)"
                        );
                        gate_failed = true;
                    } else {
                        println!(
                            "gate: 100k sharded speedup {shard_ratio:.2}× within \
                             {GATE_FACTOR}× of the armed baseline {baseline:.2}×"
                        );
                    }
                }
                _ => println!(
                    "gate: no armed 100k sharded baseline — arming this run's numbers"
                ),
            }
        }
    }

    let out = Json::obj(vec![
        ("armed", Json::Bool(!gate_failed)),
        ("bench", Json::Str("sched_scale".into())),
        ("gpus", Json::Num(GPUS as f64)),
        ("island", Json::Num(ISLAND as f64)),
        ("quick", Json::Bool(quick)),
        ("speedup_1k_vs_reference", Json::Num(speedup_1k)),
        (
            "note",
            Json::Str(
                "wall-clock of the cluster-scheduling layer (synthetic bodies); \
                 reference = pre-PR full-reprice + legacy replan; the committed armed \
                 speedup_1k_vs_reference is the regression baseline — CI fails when a \
                 run's in-process ratio drops more than 2x below it (machine-independent). \
                 'streaming' records the body layer: eager simulate_trace vs \
                 run_streaming wall time and peak retained outcomes on a \
                 duplicate-heavy trace (digest-equality asserted in-process). \
                 scales['100000'] is the sharded event-loop point: single loop \
                 vs shards-by-island + digest-only retention vs the lazy \
                 source-driven loop, bit-identical digests asserted in-process, \
                 tasks/sec + retained-event counts persisted; its armed \
                 sharded_speedup ratio gates full runs like the 1k ratio does. \
                 scales['1000000'] is the source-driven extreme: the trace is \
                 never materialized, the log is digest-only, and the run must \
                 fit a 600 s wall budget (null in quick mode / small runners). \
                 peak_rss_bytes is VmHWM sampled after each scale — a \
                 process-wide high-water mark, so read the per-scale jumps. \
                 'rank' is the dynamic rank reallocation point: the same \
                 rank-heavy stream with the policy off vs RankPolicy::paper(), \
                 resize/grow/shrink counts plus the adaptive-vs-fixed makespan \
                 and charged GPU-seconds ratios (GPU-seconds strictly lower is \
                 asserted in-process)"
                    .into(),
            ),
        ),
        ("scales", Json::Obj(scales_json)),
        ("streaming", Json::Obj(streaming_json)),
        ("colocation", colo_json),
        ("faults", faults_json),
        ("overload", overload_json),
        ("rank", rank_json),
    ]);
    if gate_failed {
        // keep the committed baseline; persist the regressed measurements
        // next to it so the CI artifact carries the diagnosis
        let path = "BENCH_sched_scale.regressed.json";
        std::fs::write(path, out.to_string_pretty() + "\n").expect("write regressed json");
        eprintln!("gate failed — regressed numbers written to {path}; {BENCH_PATH} untouched");
        std::process::exit(1);
    }
    std::fs::write(BENCH_PATH, out.to_string_pretty() + "\n").expect("write bench json");
    println!("wrote {BENCH_PATH}");
}
