//! Fig 14 — single-GPU quality ablation (llama-8b analog / gsm-syn):
//! per-adapter accuracies across the full sweep (the grey dots — high
//! variance, many near zero), best found by batching alone vs batching +
//! early exit, and best val loss confirming no quality degradation.

use alto::bench::{banner, f, pct, Table};
use alto::config::{SearchSpace, TaskSpec};
use alto::coordinator::service::{Service, ServiceConfig};
use alto::coordinator::task_runner::RunConfig;
use alto::data::synth::dataset_profile;
use alto::stats;
use alto::trajsim::SimJob;

fn main() {
    let samples = if alto::bench::quick() { 96 } else { 256 };
    let prof = dataset_profile("gsm-syn").unwrap();

    banner("Fig 14 (left): accuracy by per-adapter batch size");
    let mut t = Table::new(&[
        "batch", "sweep min", "sweep median", "sweep max",
        "batched best", "batched+EE best",
    ]);
    let mut t2 = Table::new(&["batch", "best val (no EE)", "best val (EE)", "ratio"]);
    for bs in [1usize, 2, 4, 8] {
        let space = SearchSpace {
            batch_sizes: vec![bs],
            ..SearchSpace::paper_single_gpu()
        };
        // the grey dots: every config's final accuracy, full training
        let seed = 100 + bs as u64;
        let accs: Vec<f64> = space
            .expand()
            .iter()
            .map(|hp| {
                SimJob::new(hp, prof, (3 * samples / bs).max(1), seed).final_accuracy()
            })
            .collect();
        let s = stats::summarize(&accs);

        let run = |ee: bool| {
            let spec = TaskSpec {
                name: format!("b{bs}"),
                model: "llama-8b".into(),
                dataset: "gsm-syn".into(),
                search_space: space.clone(),
                train_samples: samples,
                seed,
                ..TaskSpec::default()
            };
            let cfg = if ee {
                RunConfig::default()
            } else {
                RunConfig {
                    enable_early_exit: false,
                    enable_warmup_selection: false,
                    ..RunConfig::default()
                }
            };
            let svc = Service::new(ServiceConfig { run: cfg, ..ServiceConfig::default() });
            let o = svc.run_task_simulated(&spec).unwrap();
            // accuracy of the best-val job
            let g = &o.group_results[0];
            let hp = &g.jobs[g.best_job].hp;
            (
                SimJob::new(hp, prof, (3 * samples / bs).max(1), seed).final_accuracy(),
                o.best_val,
            )
        };
        let (acc_no_ee, val_no_ee) = run(false);
        let (acc_ee, val_ee) = run(true);
        t.row(vec![
            format!("{bs}"),
            pct(s.min),
            pct(s.median),
            pct(s.max),
            pct(acc_no_ee),
            pct(acc_ee),
        ]);
        t2.row(vec![
            format!("{bs}"),
            f(val_no_ee, 4),
            f(val_ee, 4),
            f(val_ee / val_no_ee, 3),
        ]);
    }
    t.print();
    banner("Fig 14 (right): best validation loss with vs without early exit");
    t2.print();
    println!(
        "\n(paper: individual accuracies vary wildly with many near zero; \
         early exit preserves or improves the best result by concentrating \
         resources — val-loss ratios ≈ 1.0)"
    );
}
