//! Fig 14 — single-GPU quality ablation (llama-8b analog / gsm-syn):
//! per-adapter accuracies across the full sweep (the grey dots — high
//! variance, many near zero), best found by batching alone vs batching +
//! early exit, and best val loss confirming no quality degradation.

use alto::bench::{banner, f, pct, Table};
use alto::cluster::PlacePolicy;
use alto::config::{SearchSpace, TaskSpec};
use alto::coordinator::service::{Service, ServiceConfig, TaskOutcome};
use alto::coordinator::task_runner::RunConfig;
use alto::data::synth::dataset_profile;
use alto::sched::inter::Policy;
use alto::simharness::{HarnessConfig, RankPolicy, SimEngine, Trace};
use alto::stats;
use alto::trajsim::SimJob;

fn main() {
    let samples = if alto::bench::quick() { 96 } else { 256 };
    let prof = dataset_profile("gsm-syn").unwrap();

    banner("Fig 14 (left): accuracy by per-adapter batch size");
    let mut t = Table::new(&[
        "batch", "sweep min", "sweep median", "sweep max",
        "batched best", "batched+EE best",
    ]);
    let mut t2 = Table::new(&["batch", "best val (no EE)", "best val (EE)", "ratio"]);
    for bs in [1usize, 2, 4, 8] {
        let space = SearchSpace {
            batch_sizes: vec![bs],
            ..SearchSpace::paper_single_gpu()
        };
        // the grey dots: every config's final accuracy, full training
        let seed = 100 + bs as u64;
        let accs: Vec<f64> = space
            .expand()
            .iter()
            .map(|hp| {
                SimJob::new(hp, prof, (3 * samples / bs).max(1), seed).final_accuracy()
            })
            .collect();
        let s = stats::summarize(&accs);

        let run = |ee: bool| {
            let spec = TaskSpec {
                name: format!("b{bs}"),
                model: "llama-8b".into(),
                dataset: "gsm-syn".into(),
                search_space: space.clone(),
                train_samples: samples,
                seed,
                ..TaskSpec::default()
            };
            let cfg = if ee {
                RunConfig::default()
            } else {
                RunConfig {
                    enable_early_exit: false,
                    enable_warmup_selection: false,
                    ..RunConfig::default()
                }
            };
            let svc = Service::new(ServiceConfig { run: cfg, ..ServiceConfig::default() });
            let o = svc.run_task_simulated(&spec).unwrap();
            // accuracy of the best-val job
            let g = &o.group_results[0];
            let hp = &g.jobs[g.best_job].hp;
            (
                SimJob::new(hp, prof, (3 * samples / bs).max(1), seed).final_accuracy(),
                o.best_val,
            )
        };
        let (acc_no_ee, val_no_ee) = run(false);
        let (acc_ee, val_ee) = run(true);
        t.row(vec![
            format!("{bs}"),
            pct(s.min),
            pct(s.median),
            pct(s.max),
            pct(acc_no_ee),
            pct(acc_ee),
        ]);
        t2.row(vec![
            format!("{bs}"),
            f(val_no_ee, 4),
            f(val_ee, 4),
            f(val_ee / val_no_ee, 3),
        ]);
    }
    t.print();
    banner("Fig 14 (right): best validation loss with vs without early exit");
    t2.print();
    println!(
        "\n(paper: individual accuracies vary wildly with many near zero; \
         early exit preserves or improves the best result by concentrating \
         resources — val-loss ratios ≈ 1.0)"
    );

    rank_adaptation();
}

/// Dynamic rank reallocation ablation: the same rank-heavy trace
/// through the simharness with the policy off (fixed rank) and with
/// `RankPolicy::paper()` (adaptive).  Resizes happen at segment
/// boundaries *after* the search bodies resolve, so per-task best val
/// is untouched — while plateaued max-rank tenants hand back GPUs and
/// the charged GPU-seconds strictly drop.  Both claims are asserted
/// in-process, not just printed.
fn rank_adaptation() {
    banner("Dynamic rank reallocation: adaptive vs fixed rank (rank-heavy trace)");
    let base = HarnessConfig {
        total_gpus: 16,
        island_size: 8,
        policy: Policy::Optimal,
        place: PlacePolicy::IslandFirst,
        ..HarnessConfig::default()
    };
    let n_tasks = if alto::bench::quick() { 12 } else { 16 };
    let trace = Trace::rank_heavy(n_tasks, 2800, 30.0, 7);
    let fixed = SimEngine::new(base.clone()).run(&trace).unwrap();
    let adaptive = SimEngine::new(HarnessConfig {
        rank: RankPolicy::paper(),
        ..base
    })
    .run(&trace)
    .unwrap();
    assert_eq!(fixed.resizes, 0, "the default policy must stay off");

    let mean_val = |outs: &[TaskOutcome]| {
        outs.iter().map(|o| o.best_val).sum::<f64>() / outs.len() as f64
    };
    let mut t = Table::new(&["metric", "fixed rank", "adaptive", "ratio"]);
    let rows: [(&str, f64, f64); 3] = [
        ("mean best val", mean_val(&fixed.outcomes), mean_val(&adaptive.outcomes)),
        ("charged GPU-seconds", fixed.gpu_seconds, adaptive.gpu_seconds),
        ("makespan (s)", fixed.makespan, adaptive.makespan),
    ];
    for (label, fx, ad) in rows {
        t.row(vec![label.into(), f(fx, 2), f(ad, 2), f(ad / fx, 3)]);
    }
    t.row(vec![
        "resizes (grow/shrink)".into(),
        "0 (0/0)".into(),
        format!(
            "{} ({}/{})",
            adaptive.resizes, adaptive.rank_grows, adaptive.rank_shrinks
        ),
        "-".into(),
    ]);
    t.print();

    // quality no worse: the bodies are simulated at admission-frozen
    // hyperparameters, so every task's best val must survive bit-level
    for (i, (a, b)) in adaptive.outcomes.iter().zip(&fixed.outcomes).enumerate() {
        assert!(
            a.best_val <= b.best_val + 1e-12,
            "task {i}: adaptive rank degraded best val ({} vs {})",
            a.best_val,
            b.best_val
        );
    }
    assert!(
        adaptive.rank_shrinks > 0 && adaptive.rank_grows > 0,
        "the rank-heavy trace must exercise both directions of the policy"
    );
    assert!(
        adaptive.gpu_seconds < fixed.gpu_seconds,
        "adaptive rank must strictly lower charged GPU-seconds ({} vs {})",
        adaptive.gpu_seconds,
        fixed.gpu_seconds
    );
    println!(
        "\n(adaptive rank: quality preserved per task, charged GPU-seconds \
         {} -> {} — plateaued max-rank tenants hand back GPUs mid-flight)",
        f(fixed.gpu_seconds, 1),
        f(adaptive.gpu_seconds, 1)
    );
}
