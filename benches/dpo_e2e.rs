//! Fig 11 — DPO on the preference workload: end-to-end speedup over
//! sequential training for Batched-LoRA and ALTO (batched + early exit),
//! with best preference accuracy preserved (paper: 4.7× vs sequential,
//! 2.7× vs batched alone, same 76.2% accuracy).  Timing from the cost
//! models + simulated execution; accuracy from the trajectory simulator;
//! plus a REAL (PJRT) mini-run when artifacts are present.

use alto::bench::{banner, f, pct, Table};
use alto::cluster::gpu::GpuSpec;
use alto::config::{SearchSpace, TaskSpec, MODEL_FAMILY};
use alto::coordinator::service::{Service, ServiceConfig};
use alto::coordinator::task_runner::RunConfig;
use alto::data::synth::dataset_profile;
use alto::parallel::baselines::Sequential;
use alto::parallel::workload::{Strategy, Workload};
use alto::trajsim::SimJob;

fn main() {
    let samples = if alto::bench::quick() { 96 } else { 256 };
    // paper: 60 configs, per-adapter batch ∈ {2,4,8,16}, qwen-32b scale
    let space = SearchSpace {
        lrs: vec![1e-5, 5e-5, 1e-4, 3e-4, 5e-4],
        ranks: vec![16, 32, 64],
        batch_sizes: vec![2, 4, 8, 16],
    };
    let spec = TaskSpec {
        name: "dpo".into(),
        model: "qwen-32b".into(),
        dataset: "pref-syn".into(),
        objective: alto::config::Objective::Dpo,
        search_space: space.clone(),
        num_gpus: 2,
        train_samples: samples,
        seq_len: 512,
        seed: 17,
        ..TaskSpec::default()
    };

    // sequential baseline: every job alone, to completion
    let gpu = GpuSpec::h100_sxm5();
    let model = MODEL_FAMILY.get("qwen-32b").unwrap();
    let mut seq_time = 0.0;
    for hp in space.expand() {
        let steps = (3 * samples / hp.batch_size).max(1);
        let w = Workload {
            model: model.clone(),
            ranks: vec![hp.rank],
            batch_per_adapter: hp.batch_size,
            seq_len: 512,
        };
        // DPO ≈ 2× SFT cost (policy + reference forward, paper §6 model)
        seq_time += 2.0 * Sequential.step_time(&w, &gpu, 2).total() * steps as f64;
    }

    let run = |ee: bool| {
        let cfg = if ee {
            RunConfig::default()
        } else {
            RunConfig {
                enable_early_exit: false,
                enable_warmup_selection: false,
                ..RunConfig::default()
            }
        };
        let svc = Service::new(ServiceConfig { run: cfg, ..ServiceConfig::default() });
        let o = svc.run_task_simulated(&spec).unwrap();
        // DPO factor 2 on the simulated duration as well
        (2.0 * o.actual_duration, o)
    };
    let (t_batched, o_batched) = run(false);
    let (t_alto, o_alto) = run(true);

    // best preference accuracy per system
    let prof = dataset_profile("pref-syn").unwrap();
    let best_acc = |o: &alto::coordinator::service::TaskOutcome| {
        let mut best = 0.0f64;
        for g in &o.group_results {
            let j = &g.jobs[g.best_job];
            let steps = (3 * samples / j.hp.batch_size).max(1);
            best = best.max(SimJob::new(&j.hp, prof, steps, spec.seed).reward_accuracy());
        }
        best
    };

    banner("Fig 11: DPO end-to-end (qwen-32b analog, 60 configs, pref-syn)");
    let mut t = Table::new(&["system", "time (s)", "speedup vs seq", "best pref acc"]);
    t.row(vec!["Sequential".into(), f(seq_time, 0), "1.0x".into(), "-".into()]);
    t.row(vec![
        "Batched-LoRA".into(),
        f(t_batched, 0),
        format!("{:.1}x", seq_time / t_batched),
        pct(best_acc(&o_batched)),
    ]);
    t.row(vec![
        "ALTO (batched + EE)".into(),
        f(t_alto, 0),
        format!("{:.1}x", seq_time / t_alto),
        pct(best_acc(&o_alto)),
    ]);
    t.print();
    println!(
        "(paper: 4.7x vs sequential, 2.7x vs batched alone, identical \
         76.2% best accuracy with and without early exit)"
    );

    if std::path::Path::new("artifacts/manifest.json").exists() && !alto::bench::quick() {
        if let Err(e) = real_mini() {
            println!("(real DPO mini-run failed: {e:#})");
        }
    }
}

/// Real PJRT DPO mini-run: verifies training actually improves reward
/// accuracy through the compiled dpo_step.
fn real_mini() -> anyhow::Result<()> {
    use alto::data::corpus::PrefCorpus;
    use alto::runtime::{Manifest, Runtime, Session};
    banner("real (CPU PJRT) DPO mini-run: nano backbone, 2 adapters");
    let rt = Runtime::cpu()?;
    let m = Manifest::load("artifacts")?;
    let key = "dpo_nano_n2_b2_t32_r8";
    let spec = m.get(key)?.clone();
    let pc = PrefCorpus::build(256, spec.t, 5);
    let mut sess = Session::new(&rt, &m, key, &[8, 4], &[5e-3, 1e-3], 3)?;
    let vb = pc.val_batch(spec.n, spec.b);
    let (l0, a0) = sess.dpo_eval(&vb)?;
    for s in 0..60u64 {
        let b = pc.train_batch(spec.n, spec.b, s, 9);
        sess.dpo_step(&b)?;
    }
    let (l1, a1) = sess.dpo_eval(&vb)?;
    println!("  val loss {l0:?} → {l1:?}");
    println!("  reward acc {a0:?} → {a1:?}");
    Ok(())
}
