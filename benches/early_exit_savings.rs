//! Fig 15 — training samples saved by each early-exit pattern across
//! seven model–dataset combinations (six SFT + one DPO), with the
//! identical detector parameters the paper uses (w=2, p=2, τ_gap=0.1,
//! τ_slope=0.001, 5% warmup, 25% retention), plus the best-val-loss
//! quality ratio w/ vs w/o early exit (≈ 1.0 = no quality loss).

use alto::bench::{banner, f, pct, Table};
use alto::config::{SearchSpace, TaskSpec};
use alto::coordinator::service::{Service, ServiceConfig};
use alto::coordinator::task_runner::RunConfig;

fn spec(model: &str, ds: &str, seed: u64, samples: usize) -> TaskSpec {
    TaskSpec {
        name: format!("{model}/{ds}"),
        model: model.into(),
        dataset: ds.into(),
        search_space: SearchSpace::paper_single_gpu(),
        train_samples: samples,
        seq_len: 512,
        seed,
        ..TaskSpec::default()
    }
}

fn main() {
    let samples = if alto::bench::quick() { 96 } else { 256 };
    let combos = [
        spec("llama-8b", "gsm-syn", 1, samples),
        spec("llama-8b", "instr-syn", 2, samples),
        spec("llama-8b", "reason-syn", 3, samples),
        spec("qwen-7b", "gsm-syn", 4, samples),
        spec("qwen-7b", "instr-syn", 5, samples),
        spec("qwen-7b", "reason-syn", 6, samples),
        spec("qwen-32b", "pref-syn", 7, samples),
    ];

    banner("Fig 15: samples saved by detector (identical thresholds everywhere)");
    let mut t = Table::new(&[
        "model/dataset", "saved total", "underperf", "overfit", "diverge",
        "quality ratio",
    ]);
    let svc = Service::new(ServiceConfig::default());
    let svc_off = Service::new(ServiceConfig {
        run: RunConfig {
            enable_early_exit: false,
            enable_warmup_selection: false,
            ..RunConfig::default()
        },
        ..ServiceConfig::default()
    });
    let mut sft_under_share = vec![];
    for s in &combos {
        let on = svc.run_task_simulated(s).unwrap();
        let off = svc_off.run_task_simulated(s).unwrap();
        let saved_total: usize = on.saved_by_reason.values().sum();
        let get = |k: &str| *on.saved_by_reason.get(k).unwrap_or(&0) as f64;
        let share = |k: &str| {
            if saved_total == 0 { 0.0 } else { get(k) / saved_total as f64 }
        };
        if s.dataset != "pref-syn" {
            sft_under_share.push(share("underperforming"));
        }
        t.row(vec![
            s.name.clone(),
            pct(saved_total as f64 / on.samples_budget as f64),
            pct(share("underperforming")),
            pct(share("overfitting")),
            pct(share("diverging")),
            f(on.best_val / off.best_val, 3),
        ]);
    }
    t.print();
    let mean_under = sft_under_share.iter().sum::<f64>() / sft_under_share.len() as f64;
    println!(
        "\nmean SFT underperformance share of savings: {} \
         (paper: ~66%; overfit+divergence contribute proportionally more \
         in DPO; quality ratios at or near 1.0 confirm no quality loss)",
        pct(mean_under)
    );
}
