//! Fig 9 — end-to-end training speedup across single- and multi-GPU
//! configurations: ALTO (batched grouped GEMM + adapter parallelism +
//! early exit) vs Sequential, mLoRA, LoRAFusion and Pipeline Parallelism,
//! training the paper's 60 (single-GPU) / 64 (multi-GPU) heterogeneous
//! adapters across three datasets.  Speedup normalized to LoRAFusion
//! (as in the paper's figure).

use alto::bench::{banner, f, Table};
use alto::cluster::gpu::GpuSpec;
use alto::config::{SearchSpace, TaskSpec, MODEL_FAMILY};
use alto::coordinator::service::{Service, ServiceConfig};
use alto::coordinator::task_runner::RunConfig;
use alto::parallel::baselines::{LoraFusion, MLora, PipelineParallel, Sequential};
use alto::parallel::workload::{Strategy, Workload};

/// Makespan of a baseline that runs every job to completion (no early
/// exit), co-locating up to `slots` adapters per pass where the system
/// supports it.
fn baseline_makespan(
    strat: &dyn Strategy,
    model: &str,
    space: &SearchSpace,
    epochs: usize,
    samples: usize,
    seq: usize,
    slots: usize,
    gpus: usize,
) -> f64 {
    let gpu = GpuSpec::h100_sxm5();
    let m = MODEL_FAMILY.get(model).unwrap();
    let mut total = 0.0;
    // homogeneous batch groups, run in waves of `slots`
    for &bs in &space.batch_sizes {
        let steps = (epochs * samples / bs).max(1);
        let group: Vec<usize> = space
            .ranks
            .iter()
            .flat_map(|&r| space.lrs.iter().map(move |_| r))
            .collect();
        let colocate = if strat.name() == "sequential" { 1 } else { slots };
        for wave in group.chunks(colocate) {
            let w = Workload {
                model: m.clone(),
                ranks: wave.to_vec(),
                batch_per_adapter: bs,
                seq_len: seq,
            };
            // step_time advances all wave adapters one step
            total += strat.step_time(&w, &gpu, gpus).total() * steps as f64;
        }
    }
    total
}

fn alto_makespan(model: &str, ds: &str, space: &SearchSpace, epochs: usize,
                 samples: usize, seq: usize, gpus: usize, ee: bool) -> f64 {
    let spec = TaskSpec {
        name: "bench".into(),
        model: model.into(),
        dataset: ds.into(),
        search_space: space.clone(),
        epochs,
        num_gpus: gpus,
        seq_len: seq,
        train_samples: samples,
        seed: 5,
        ..TaskSpec::default()
    };
    let run = if ee {
        RunConfig::default()
    } else {
        RunConfig {
            enable_early_exit: false,
            enable_warmup_selection: false,
            ..RunConfig::default()
        }
    };
    let svc = Service::new(ServiceConfig { run, ..ServiceConfig::default() });
    svc.run_task_simulated(&spec).unwrap().actual_duration
}

fn main() {
    let samples = if alto::bench::quick() { 96 } else { 192 };
    let seq = 512;
    let single = SearchSpace::paper_single_gpu(); // 60 configs
    let multi = SearchSpace::paper_multi_gpu(); // 64 configs

    let cases: [(&str, usize, &SearchSpace); 4] = [
        ("llama-8b", 1, &single),
        ("qwen-7b", 1, &single),
        ("qwen-32b", 2, &multi),
        ("llama-70b", 4, &multi),
    ];

    for ds in ["gsm-syn", "instr-syn", "reason-syn"] {
        banner(&format!("Fig 9 ({ds}): makespan (s) and speedup vs LoRAFusion"));
        let mut t = Table::new(&[
            "model(GPUs)", "Sequential", "mLoRA", "LoRAFusion", "PP", "ALTO",
            "ALTO no-EE", "speedup",
        ]);
        for (model, gpus, space) in cases.iter() {
            let seqs = baseline_makespan(&Sequential, model, space, 3, samples, seq, 4, *gpus);
            let ml = baseline_makespan(&MLora, model, space, 3, samples, seq, 4, *gpus);
            let lf = baseline_makespan(&LoraFusion, model, space, 3, samples, seq, 4, *gpus);
            let pp = baseline_makespan(&PipelineParallel, model, space, 3, samples, seq, 4, *gpus);
            let alto = alto_makespan(model, ds, space, 3, samples, seq, *gpus, true);
            let alto_noee = alto_makespan(model, ds, space, 3, samples, seq, *gpus, false);
            t.row(vec![
                format!("{model}({gpus})"),
                f(seqs, 0),
                f(ml, 0),
                f(lf, 0),
                f(pp, 0),
                f(alto, 0),
                f(alto_noee, 0),
                format!("{:.1}x", lf / alto),
            ]);
        }
        t.print();
    }
    println!(
        "\n(paper: up to 9.5x single-GPU and 13.8x multi-GPU vs LoRAFusion; \
         the gain composes batched execution, adapter parallelism and \
         early exit — the last column isolates the full system)"
    );
}
