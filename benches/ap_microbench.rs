//! Fig 13 — Adapter Parallelism microbenchmark: speedup over FSDP across
//! per-adapter batch sizes on 4×H100 (8 adapters, seq 256), vs TP, mLoRA
//! and LoRAFusion.  AP peaks in the small-batch regime (paper: 4.7× at
//! bs=2) and keeps its edge at bs=8.

use alto::bench::{banner, f, Table};
use alto::cluster::gpu::GpuSpec;
use alto::config::MODEL_FAMILY;
use alto::parallel::baselines::{Alto, Fsdp, LoraFusion, MLora, TensorParallel};
use alto::parallel::workload::{Strategy, Workload};

fn main() {
    let gpu = GpuSpec::h100_sxm5();
    let model = MODEL_FAMILY.get("llama-8b").unwrap();
    banner("Fig 13: step time (ms) for 8 adapters, seq 256, 4×H100");
    let mut t = Table::new(&[
        "per-adapter bs", "FSDP", "TP", "mLoRA", "LoRAFusion", "AP (ours)",
        "AP vs FSDP",
    ]);
    let mut peak: (usize, f64) = (0, 0.0);
    for bs in [1usize, 2, 4, 8] {
        let w = Workload {
            model: model.clone(),
            ranks: vec![16; 8],
            batch_per_adapter: bs,
            seq_len: 256,
        };
        let ms = |s: &dyn Strategy| s.step_time(&w, &gpu, 4).total() * 1e3;
        let fsdp = ms(&Fsdp);
        let ap = ms(&Alto);
        let speed = fsdp / ap;
        if speed > peak.1 {
            peak = (bs, speed);
        }
        t.row(vec![
            format!("{bs}{}", if bs < 4 { " (FSDP padded)" } else { "" }),
            f(fsdp, 1),
            f(ms(&TensorParallel), 1),
            f(ms(&MLora), 1),
            f(ms(&LoraFusion), 1),
            f(ap, 1),
            format!("{speed:.1}x"),
        ]);
    }
    t.print();
    println!(
        "\nAP peak speedup: {:.1}x at per-adapter batch {} \
         (paper: 4.7x at bs=2; FSDP cannot run bs<4 on 4 ranks — padded, \
         dashed bars in the paper)",
        peak.1, peak.0
    );

    banner("breakdown at bs=2 (where AP peaks)");
    let w = Workload {
        model: model.clone(),
        ranks: vec![16; 8],
        batch_per_adapter: 2,
        seq_len: 256,
    };
    let mut t = Table::new(&["strategy", "compute", "memory", "lora", "comm", "launch", "bubble", "idle%"]);
    let rows: Vec<(&str, Box<dyn Strategy>)> = vec![
        ("FSDP", Box::new(Fsdp)),
        ("TP", Box::new(TensorParallel)),
        ("mLoRA", Box::new(MLora)),
        ("LoRAFusion", Box::new(LoraFusion)),
        ("AP (ours)", Box::new(Alto)),
    ];
    for (name, s) in rows {
        let b = s.step_time(&w, &gpu, 4);
        t.row(vec![
            name.into(),
            f(b.compute_s * 1e3, 2),
            f(b.memory_s * 1e3, 2),
            f(b.lora_s * 1e3, 2),
            f(b.comm_s * 1e3, 2),
            f(b.launch_s * 1e3, 2),
            f(b.bubble_s * 1e3, 2),
            f(b.idle_frac * 100.0, 0),
        ]);
    }
    t.print();
    println!("(all ms; AP pays the weight all-gather once per step but \
              never idles a rank and never communicates adapter gradients)");
}
