//! Fig 10 — quality of the best configuration ALTO finds vs
//! expert-recommended fixed hyperparameters (the paper's Unsloth / Tinker
//! rows): GSM accuracy (higher better) and completion loss on the other
//! datasets (lower better).  ALTO's search matches or beats the fixed
//! recipes, and expert defaults often miss the best config.

use alto::bench::{banner, f, pct, Table};
use alto::config::{HyperParams, SearchSpace, TaskSpec};
use alto::coordinator::service::{Service, ServiceConfig};
use alto::data::synth::dataset_profile;
use alto::trajsim::SimJob;

/// Published default recipes, mapped onto the search dimensions.
/// (Unsloth docs: lr 2e-4, r 16, small batch; Tinker-style: lr 1e-4,
/// r 32, batch 4.)
const EXPERTS: [(&str, HyperParams); 2] = [
    ("unsloth-default", HyperParams { lr: 2e-4, rank: 16, batch_size: 8 }),
    ("tinker-default", HyperParams { lr: 1e-4, rank: 32, batch_size: 4 }),
];

fn main() {
    let samples = if alto::bench::quick() { 96 } else { 256 };
    banner("Fig 10(a): GSM accuracy — ALTO search vs expert defaults");
    let mut t = Table::new(&["model", "ALTO best", "unsloth", "tinker"]);
    for (model, seed) in [("llama-8b", 21u64), ("qwen-7b", 24)] {
        let spec = TaskSpec {
            name: model.into(),
            model: model.into(),
            dataset: "gsm-syn".into(),
            search_space: SearchSpace::paper_single_gpu(),
            train_samples: samples,
            seed,
            ..TaskSpec::default()
        };
        let svc = Service::new(ServiceConfig::default());
        let outcome = svc.run_task_simulated(&spec).unwrap();
        // map the winning job's best-val to accuracy via the same
        // trajectory object the executor sampled
        let prof = dataset_profile("gsm-syn").unwrap();
        let total = 3 * samples; // epochs × samples at bs=1 granularity
        let acc_of = |hp: &HyperParams, s: u64| {
            SimJob::new(hp, prof, total / hp.batch_size.max(1), s).final_accuracy()
        };
        // ALTO: accuracy of the best-val job it retained
        let best_hp = {
            let mut best: Option<(&HyperParams, f64)> = None;
            for g in &outcome.group_results {
                let j = &g.jobs[g.best_job];
                if best.is_none() || j.best_val < best.as_ref().unwrap().1 {
                    best = Some((&j.hp, j.best_val));
                }
            }
            best.unwrap().0.clone()
        };
        t.row(vec![
            model.into(),
            pct(acc_of(&best_hp, seed)),
            pct(acc_of(&EXPERTS[0].1, seed)),
            pct(acc_of(&EXPERTS[1].1, seed)),
        ]);
    }
    t.print();

    banner("Fig 10(b,c): completion loss — ALTO search vs expert defaults");
    let mut t = Table::new(&["model/dataset", "ALTO best", "unsloth", "tinker"]);
    for (model, ds, seed) in [
        ("llama-8b", "instr-syn", 31u64),
        ("llama-8b", "reason-syn", 32),
        ("qwen-7b", "instr-syn", 33),
        ("qwen-7b", "reason-syn", 34),
    ] {
        let spec = TaskSpec {
            name: model.into(),
            model: model.into(),
            dataset: ds.into(),
            search_space: SearchSpace::paper_single_gpu(),
            train_samples: samples,
            seed,
            ..TaskSpec::default()
        };
        let svc = Service::new(ServiceConfig::default());
        let outcome = svc.run_task_simulated(&spec).unwrap();
        let prof = dataset_profile(ds).unwrap();
        let total = 3 * samples;
        let loss_of = |hp: &HyperParams, s: u64| {
            SimJob::new(hp, prof, total / hp.batch_size.max(1), s).best_val_loss()
        };
        t.row(vec![
            format!("{model}/{ds}"),
            f(outcome.best_val, 4),
            f(loss_of(&EXPERTS[0].1, seed), 4),
            f(loss_of(&EXPERTS[1].1, seed), 4),
        ]);
    }
    t.print();
    println!(
        "\n(paper: ALTO matches or exceeds expert-recommended settings on \
         every model–dataset combination; fixed recipes frequently miss \
         the best configuration — the motivation for systematic search)"
    );
}
