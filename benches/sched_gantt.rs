//! Fig 5 — shortest-job-first vs makespan-aware inter-task scheduling:
//! the didactic instance where SJF fragments the cluster, plus solver
//! quality/latency statistics on random paper-scale instances.  Gantt
//! rows also show the *concrete* GPU indices each planned task pins
//! (`Schedule::concretize` over the cluster topology).

use alto::bench::{banner, f, time_median, Table};
use alto::cluster::{PlacePolicy, Topology};
use alto::sched::solver::{
    fcfs_schedule, lower_bound, lpt_schedule, sjf_schedule, solve, SchedTask, Schedule,
};
use alto::util::rng::Pcg32;

fn gantt(label: &str, tasks: &[SchedTask], s: &Schedule, gpus: usize) {
    println!("{label}: makespan {:.1}s", s.makespan);
    let concrete = s
        .concretize(tasks, &Topology::h100_nodes(gpus), PlacePolicy::IslandFirst)
        .unwrap();
    let scale = 40.0 / s.makespan.max(1e-9);
    let mut placements = s.placements.clone();
    placements.sort_by(|a, b| {
        alto::sched::finite_last_cmp(a.start, b.start).then(a.id.cmp(&b.id))
    });
    for p in &placements {
        let d = tasks.iter().find(|t| t.id == p.id).unwrap().duration;
        let pre = (p.start * scale) as usize;
        let len = ((d * scale) as usize).max(1);
        println!(
            "  task{:<2} {}{} ({} GPUs on {}, {:.1}s @ {:.1}s)",
            p.id,
            " ".repeat(pre),
            "#".repeat(len),
            p.gpus,
            concrete.gpus_of(p.id).map(|g| g.to_string()).unwrap_or_default(),
            d,
            p.start
        );
    }
}

fn main() {
    banner("Fig 5: SJF vs makespan-aware packing (2-GPU didactic instance)");
    let tasks = [
        SchedTask { id: 0, duration: 1.0, gpus: 1 },
        SchedTask { id: 1, duration: 1.0, gpus: 1 },
        SchedTask { id: 2, duration: 1.5, gpus: 1 },
        SchedTask { id: 3, duration: 2.0, gpus: 2 },
    ];
    gantt("(a) SJF", &tasks, &sjf_schedule(&tasks, 2), 2);
    gantt("(b) ALTO (exact B&B)", &tasks, &solve(&tasks, 2).unwrap(), 2);

    banner("solver quality + latency on random 8-GPU instances");
    let mut t = Table::new(&["n tasks", "opt/LB", "SJF/opt", "FCFS/opt", "LPT/opt", "solve ms"]);
    let trials = if alto::bench::quick() { 5 } else { 20 };
    for n in [4usize, 6, 8, 10, 12] {
        let mut rng = Pcg32::seeded(n as u64);
        let (mut r_lb, mut r_sjf, mut r_fcfs, mut r_lpt, mut ms) =
            (0.0, 0.0, 0.0, 0.0, 0.0);
        for _ in 0..trials {
            let tasks: Vec<SchedTask> = (0..n)
                .map(|i| SchedTask {
                    id: i,
                    duration: rng.uniform(1.0, 20.0),
                    gpus: *rng.choice(&[1, 1, 1, 2, 2, 4]),
                })
                .collect();
            let tm = time_median(0, 1, || {
                let _ = solve(&tasks, 8).unwrap();
            });
            let opt = solve(&tasks, 8).unwrap().makespan;
            r_lb += opt / lower_bound(&tasks, 8);
            r_sjf += sjf_schedule(&tasks, 8).makespan / opt;
            r_fcfs += fcfs_schedule(&tasks, 8).makespan / opt;
            r_lpt += lpt_schedule(&tasks, 8).makespan / opt;
            ms += tm * 1e3;
        }
        let k = trials as f64;
        t.row(vec![
            format!("{n}"),
            f(r_lb / k, 3),
            f(r_sjf / k, 3),
            f(r_fcfs / k, 3),
            f(r_lpt / k, 3),
            f(ms / k, 2),
        ]);
    }
    t.print();
    println!("(paper §7.2: the CP solver finds the optimum in < 1 s for all \
              tested instances — ours solves n ≤ 12 in milliseconds)");
}
