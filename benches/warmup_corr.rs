//! Fig 7 / Fig 16 — warmup-based exiting validity: Spearman ρ between
//! warmup-boundary and final validation loss, top-25% coverage, and
//! whether the eventual best config survives the warmup cut, swept over
//! the warmup percentage.  5% is where everything stabilizes (the
//! paper's default).

use alto::bench::{banner, f, pct, Table};
use alto::config::SearchSpace;
use alto::data::synth::dataset_profile;
use alto::stats::{best_in_topk, spearman, topk_coverage};
use alto::trajsim::{Regime, SimJob};

const TOTAL_STEPS: usize = 600;

fn main() {
    let combos = [
        ("llama-8b/gsm-syn", "gsm-syn", 41u64),
        ("llama-8b/instr-syn", "instr-syn", 42),
        ("llama-8b/reason-syn", "reason-syn", 43),
        ("qwen-7b/gsm-syn", "gsm-syn", 44),
        ("qwen-7b/instr-syn", "instr-syn", 45),
        ("qwen-7b/reason-syn", "reason-syn", 46),
        ("qwen-32b/pref-syn", "pref-syn", 47),
    ];
    banner("Fig 16: early-exit prediction quality vs warmup percentage");
    let mut t = Table::new(&[
        "warmup%", "Spearman ρ (mean)", "top-25% coverage", "best in top-25%",
    ]);
    for wpct in [1usize, 2, 5, 10, 20] {
        let warm_step = (TOTAL_STEPS * wpct / 100).max(1);
        let mut rho_sum = 0.0;
        let mut cov_sum = 0.0;
        let mut best_hits = 0usize;
        for (_, ds, seed) in combos {
            let prof = dataset_profile(ds).unwrap();
            let jobs: Vec<SimJob> = SearchSpace::paper_single_gpu()
                .expand()
                .iter()
                .map(|hp| SimJob::new(hp, prof, TOTAL_STEPS, seed))
                .collect();
            // "well-behaved" = survived warmup (non-diverging), paper Fig 7
            let well: Vec<&SimJob> =
                jobs.iter().filter(|j| j.regime != Regime::Diverging).collect();
            let early: Vec<f64> = well.iter().map(|j| j.val_loss(warm_step)).collect();
            let fin: Vec<f64> = well.iter().map(|j| j.best_val_loss()).collect();
            rho_sum += spearman(&early, &fin);
            cov_sum += topk_coverage(&early, &fin, 0.25);
            if best_in_topk(&early, &fin, 0.25) {
                best_hits += 1;
            }
        }
        let k = combos.len() as f64;
        t.row(vec![
            format!("{wpct}%"),
            f(rho_sum / k, 3),
            pct(cov_sum / k),
            format!("{best_hits}/{}", combos.len()),
        ]);
    }
    t.print();
    println!(
        "(paper: ρ stabilizes above 0.7 by 5% warmup; coverage 60–80%; the \
         best configuration is reliably inside the top quartile at 5%)"
    );

    banner("Fig 7: per-combination rank correlation at the 5% boundary");
    let mut t = Table::new(&["model/dataset", "Spearman ρ", "best in top-25%"]);
    let warm = TOTAL_STEPS / 20;
    for (label, ds, seed) in combos {
        let prof = dataset_profile(ds).unwrap();
        let jobs: Vec<SimJob> = SearchSpace::paper_single_gpu()
            .expand()
            .iter()
            .map(|hp| SimJob::new(hp, prof, TOTAL_STEPS, seed))
            .collect();
        let well: Vec<&SimJob> =
            jobs.iter().filter(|j| j.regime != Regime::Diverging).collect();
        let early: Vec<f64> = well.iter().map(|j| j.val_loss(warm)).collect();
        let fin: Vec<f64> = well.iter().map(|j| j.best_val_loss()).collect();
        t.row(vec![
            label.into(),
            f(spearman(&early, &fin), 3),
            if best_in_topk(&early, &fin, 0.25) { "yes" } else { "NO" }.into(),
        ]);
    }
    t.print();
}
