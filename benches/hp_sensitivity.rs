//! Fig 1 — hyperparameter sensitivity: best-val-loss distribution across
//! the paper's 60/165-config spaces, GSM-style accuracy spread, and DPO
//! reward-accuracy spread.  Sweep-scale rows run on the calibrated
//! trajectory simulator; a real tiny-family sweep (PJRT) anchors the
//! small-scale analog when artifacts are present.

use alto::bench::{banner, f, pct, Table};
use alto::config::SearchSpace;
use alto::data::synth::dataset_profile;
use alto::stats;
use alto::trajsim::SimJob;

fn main() {
    banner("Fig 1(a): best validation loss across hyperparameter configs");
    let mut t = Table::new(&[
        "model/dataset", "configs", "min", "p25", "median", "p75", "max", "max/min",
    ]);
    let combos = [
        ("llama-8b", "gsm-syn", 41u64),
        ("llama-8b", "instr-syn", 42),
        ("llama-8b", "reason-syn", 43),
        ("qwen-7b", "gsm-syn", 44),
        ("qwen-7b", "instr-syn", 45),
        ("qwen-7b", "reason-syn", 46),
    ];
    for (model, ds, seed) in combos {
        let prof = dataset_profile(ds).unwrap();
        let vals: Vec<f64> = SearchSpace::paper_single_gpu()
            .expand()
            .iter()
            .map(|hp| SimJob::new(hp, prof, 600, seed).best_val_loss())
            .collect();
        let s = stats::summarize(&vals);
        t.row(vec![
            format!("{model}/{ds}"),
            format!("{}", vals.len()),
            f(s.min, 3),
            f(s.p25, 3),
            f(s.median, 3),
            f(s.p75, 3),
            f(s.max, 3),
            f(s.max / s.min, 1),
        ]);
    }
    t.print();

    banner("Fig 1(b): GSM accuracy spread of best checkpoint per config");
    let mut t = Table::new(&["model", "best", "median", "worst", "spread"]);
    for (model, seed) in [("llama-8b", 41u64), ("qwen-7b", 44)] {
        let prof = dataset_profile("gsm-syn").unwrap();
        let accs: Vec<f64> = SearchSpace::paper_single_gpu()
            .expand()
            .iter()
            .map(|hp| SimJob::new(hp, prof, 600, seed).final_accuracy())
            .collect();
        let s = stats::summarize(&accs);
        t.row(vec![
            model.into(),
            pct(s.max),
            pct(s.median),
            pct(s.min),
            pct(s.max - s.min),
        ]);
    }
    t.print();
    println!("(paper: best 42.8% / 73.9%, worst ≈ 0%, spread up to 73.9%)");

    banner("Fig 1(c): DPO reward-accuracy spread (qwen-32b / pref-syn)");
    let prof = dataset_profile("pref-syn").unwrap();
    let accs: Vec<f64> = SearchSpace::paper_multi_gpu()
        .expand()
        .iter()
        .take(60)
        .map(|hp| SimJob::new(hp, prof, 400, 7).reward_accuracy())
        .collect();
    let s = stats::summarize(&accs);
    let mut t = Table::new(&["configs", "best", "worst", "spread"]);
    t.row(vec![
        format!("{}", accs.len()),
        pct(s.max),
        pct(s.min),
        pct(s.max - s.min),
    ]);
    t.print();
    println!("(paper: ~80% → ~53%, spread 26.7%)");

    // real anchor (PJRT tiny sweep), when artifacts exist
    if std::path::Path::new("artifacts/manifest.json").exists() && !alto::bench::quick() {
        banner("real anchor: nano sweep on PJRT (8 configs × 60 steps)");
        real_anchor();
    }
}

fn real_anchor() {
    use alto::config::HyperParams;
    use alto::coordinator::task_runner::RunConfig;
    use alto::data::corpus::Corpus;
    use alto::runtime::{Manifest, Runtime};
    use alto::train::run_real_sweep;

    let rt = Runtime::cpu().unwrap();
    let m = Manifest::load("artifacts").unwrap();
    let key = "sft_nano_n4_b2_t32_r8";
    let corpus = Corpus::build("gsm-syn", 512, 32, 32, 7).unwrap();
    let configs: Vec<HyperParams> = [1e-4, 5e-4, 2e-3, 5e-3, 1e-2, 3e-2, 1e-3, 2e-2]
        .iter()
        .map(|&lr| HyperParams { lr, rank: 8, batch_size: 2 })
        .collect();
    let cfg = RunConfig {
        enable_early_exit: false,
        enable_warmup_selection: false,
        eval_every: 10,
        ..RunConfig::default()
    };
    let out = run_real_sweep(&rt, &m, key, corpus, &configs, 60, &cfg, 3).unwrap();
    let mut t = Table::new(&["lr", "best val loss"]);
    for j in &out.result.jobs {
        t.row(vec![format!("{:.0e}", j.hp.lr), f(j.best_val, 4)]);
    }
    t.print();
    let vals: Vec<f64> = out.result.jobs.iter().map(|j| j.best_val).collect();
    let s = alto::stats::summarize(&vals);
    println!("real spread: {:.3} .. {:.3} ({:.2}x)", s.min, s.max, s.max / s.min);
}
